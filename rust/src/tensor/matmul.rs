//! Packed, register-tiled, threaded f32 matrix multiplication.
//!
//! The kernel packs B once into zero-padded column strips of width `NR`
//! (k-contiguous, so the inner loop streams one cache line of B per
//! step), then computes `MR × NR` blocks of C with the accumulators held
//! in registers for the whole k extent — C is written once per block
//! instead of once per (row, k) pair, and the B strip is re-streamed
//! once per `MR` rows instead of once per row. Row blocks are
//! partitioned across the persistent worker pool — no synchronization
//! needed. Accumulation over k is strictly sequential and skip-free,
//! which makes `A·B` and `(Bᵀ·Aᵀ)ᵀ` bit-identical for symmetric
//! operands — the workspace COMQ engine relies on this (see
//! quant/workspace.rs). That identity is a *same-kernel* property: the
//! micro-kernel is runtime-dispatched (`util::simd`, scalar mul+add vs
//! AVX2 FMA, overridable via `COMQ_KERNEL`), the kernel is chosen once
//! per `matmul_into_packed` call, and any single kernel satisfies the
//! transpose-commute contract because both orientations run the same
//! k-sequential instruction sequence.

use super::Tensor;
use crate::util::pool::{parallel_ranges, SendPtr};
use crate::util::simd::{self, Kernel};

/// Micro-kernel tile: MR rows × NR columns of C accumulated in registers
/// (4 × 16 f32 = two ymm accumulator rows per MR row under AVX2; 16 i32
/// = one zmm under AVX-512). Shared with the integer serving GEMM
/// (serve/gemm.rs) so both kernels block the same way.
pub const MR: usize = 4;
pub const NR: usize = 16;
const MIN_FLOPS_PER_THREAD: usize = 1 << 20;

/// C = A @ B; A [m, k], B [k, n] -> [m, n].
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.rows(), a.cols());
    let (k2, n) = (b.rows(), b.cols());
    assert_eq!(k, k2, "matmul inner dims {k} vs {k2}");
    let mut out = Tensor::zeros(&[m, n]);
    matmul_into(a.data(), b.data(), out.data_mut(), m, k, n);
    out
}

/// C (pre-zeroed or accumulated into) += A @ B on raw slices.
pub fn matmul_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(b.len(), k * n);
    if m == 0 || n == 0 {
        return;
    }
    let bp = pack_b(b, k, n);
    matmul_into_packed(a, &bp, c, m, k, n);
}

/// C += A @ B where `bp` is B [k, n] already packed by [`pack_b`].
/// Callers that multiply by the same B many times (the workspace sweep
/// hits the layer Gram 2·iters times per layer) pack once and reuse.
pub(crate) fn matmul_into_packed(a: &[f32], bp: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(c.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    let n_strips = n.div_ceil(NR);
    assert_eq!(bp.len(), n_strips * k * NR, "bp not packed for [{k}, {n}]");
    let n_blocks = m.div_ceil(MR);
    let min_blocks = (MIN_FLOPS_PER_THREAD / (2 * k * n * MR).max(1)).max(1);
    let c_ptr = SendPtr::new(c.as_mut_ptr());
    // one kernel per call: every tile of this product — and of the
    // transposed product a bit-identity test might compare against —
    // must run the same instruction sequence
    let kern = Kernel::active();
    parallel_ranges(n_blocks, min_blocks, |_, blocks| {
        let c = unsafe { std::slice::from_raw_parts_mut(c_ptr.ptr(), m * n) };
        // strip-outer order keeps one B strip (k×NR floats) hot across
        // this thread's row blocks
        for s in 0..n_strips {
            let strip = &bp[s * k * NR..(s + 1) * k * NR];
            let j0 = s * NR;
            let cols = NR.min(n - j0);
            for blk in blocks.clone() {
                let i0 = blk * MR;
                let rows = MR.min(m - i0);
                let mut acc = [[0.0f32; NR]; MR];
                simd::dot_f32(kern, &a[i0 * k..], k, rows, strip, k, &mut acc);
                for (r, accr) in acc.iter().take(rows).enumerate() {
                    let crow = &mut c[(i0 + r) * n + j0..(i0 + r) * n + j0 + cols];
                    for (cv, av) in crow.iter_mut().zip(&accr[..cols]) {
                        *cv += av;
                    }
                }
            }
        }
    });
}

/// Pack B [k, n] into column strips of width NR, k-contiguous and
/// zero-padded on the last strip: packed[s][kk][l] = B[kk][s·NR + l].
pub(crate) fn pack_b(b: &[f32], k: usize, n: usize) -> Vec<f32> {
    let n_strips = n.div_ceil(NR);
    let mut bp = vec![0.0f32; n_strips * k * NR];
    let bp_ptr = SendPtr::new(bp.as_mut_ptr());
    // memory-bound; only fan out for panels that dwarf the hand-off cost
    let min_strips = (1 << 16) / (k * NR).max(1) + 1;
    parallel_ranges(n_strips, min_strips, |_, strips| {
        let bp = unsafe { std::slice::from_raw_parts_mut(bp_ptr.ptr(), n_strips * k * NR) };
        for s in strips {
            let j0 = s * NR;
            let cols = NR.min(n - j0);
            for kk in 0..k {
                let src = &b[kk * n + j0..kk * n + j0 + cols];
                bp[s * k * NR + kk * NR..s * k * NR + kk * NR + cols].copy_from_slice(src);
            }
        }
    });
    bp
}

/// crow += av * brow  (the vectorizable elementwise kernel; also used by
/// the COMQ sweep engines for the rank-1 residual update).
#[inline]
pub(crate) fn axpy(av: f32, brow: &[f32], crow: &mut [f32]) {
    let n = crow.len();
    let (bc, bt) = brow.split_at(n - n % 8);
    let (cc, ct) = crow.split_at_mut(n - n % 8);
    for (c8, b8) in cc.chunks_exact_mut(8).zip(bc.chunks_exact(8)) {
        for l in 0..8 {
            c8[l] += av * b8[l];
        }
    }
    for (c1, b1) in ct.iter_mut().zip(bt) {
        *c1 += av * b1;
    }
}

/// G = Aᵀ A for A [r, m] -> [m, m] (the calibration Gram kernel).
/// Symmetric; computes the upper triangle and mirrors.
pub fn matmul_at_a(a: &Tensor) -> Tensor {
    let (r, m) = (a.rows(), a.cols());
    let ad = a.data();
    let mut g = Tensor::zeros(&[m, m]);
    let g_ptr = SendPtr::new(g.data_mut().as_mut_ptr());
    parallel_ranges(m, 8, |_, cols| {
        let gd = unsafe { std::slice::from_raw_parts_mut(g_ptr.ptr(), m * m) };
        for i in cols {
            // row i of G: sum_r a[r,i] * a[r, i..]
            let gi = &mut gd[i * m..(i + 1) * m];
            for row in 0..r {
                let arow = &ad[row * m..(row + 1) * m];
                let ai = arow[i];
                if ai == 0.0 {
                    continue;
                }
                axpy(ai, &arow[i..], &mut gi[i..]);
            }
        }
    });
    // mirror upper -> lower
    for i in 0..m {
        for j in 0..i {
            let v = g.data()[j * m + i];
            g.data_mut()[i * m + j] = v;
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn naive(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k, n) = (a.rows(), a.cols(), b.cols());
        let mut c = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0f64;
                for l in 0..k {
                    s += a.at2(i, l) as f64 * b.at2(l, j) as f64;
                }
                c.data_mut()[i * n + j] = s as f32;
            }
        }
        c
    }

    #[test]
    fn matches_naive_various_shapes() {
        let mut rng = Rng::new(1);
        for &(m, k, n) in &[
            (1, 1, 1),
            (3, 5, 7),
            (17, 33, 9),
            (64, 48, 96),
            (100, 1, 50),
            (5, 300, 16),  // strip-exact n, k beyond one cache line
            (4, 7, 16),    // exactly one full strip
            (9, 11, 35),   // padded tail strip + tail row block
        ] {
            let a = Tensor::new(&[m, k], rng.normal_vec(m * k));
            let b = Tensor::new(&[k, n], rng.normal_vec(k * n));
            let c = matmul(&a, &b);
            let expect = naive(&a, &b);
            assert!(
                c.max_abs_diff(&expect) < 1e-3 * (k as f32).sqrt(),
                "shape ({m},{k},{n})"
            );
        }
    }

    #[test]
    fn symmetric_transpose_bit_identity() {
        // For symmetric G: (Rᵀ·G)[j][i] must equal (G·R)[i][j] bit-for-
        // bit — the contract the workspace sweep engine relies on.
        let mut rng = Rng::new(8);
        let (m, n) = (37, 21);
        let x = Tensor::new(&[50, m], rng.normal_vec(50 * m));
        let g = matmul_at_a(&x);
        let r = Tensor::new(&[m, n], rng.normal_vec(m * n));
        let p = matmul(&g, &r);
        let pt = matmul(&r.transpose2(), &g);
        for i in 0..m {
            for j in 0..n {
                assert_eq!(p.at2(i, j).to_bits(), pt.at2(j, i).to_bits(), "({i},{j})");
            }
        }
    }

    #[test]
    fn at_a_matches_explicit() {
        let mut rng = Rng::new(2);
        for &(r, m) in &[(10, 4), (64, 33), (7, 129)] {
            let a = Tensor::new(&[r, m], rng.normal_vec(r * m));
            let g = matmul_at_a(&a);
            let expect = matmul(&a.transpose2(), &a);
            assert!(g.max_abs_diff(&expect) < 1e-3, "shape ({r},{m})");
            // symmetry is exact by construction
            for i in 0..m {
                for j in 0..m {
                    assert_eq!(g.at2(i, j), g.at2(j, i));
                }
            }
        }
    }

    #[test]
    fn identity() {
        let n = 16;
        let mut eye = Tensor::zeros(&[n, n]);
        for i in 0..n {
            eye.data_mut()[i * n + i] = 1.0;
        }
        let mut rng = Rng::new(3);
        let b = Tensor::new(&[n, 5], rng.normal_vec(n * 5));
        assert_eq!(matmul(&eye, &b), b);
    }

    #[test]
    fn accumulates_into_c() {
        let a = Tensor::new(&[2, 2], vec![1., 0., 0., 1.]);
        let b = Tensor::new(&[2, 2], vec![1., 2., 3., 4.]);
        let mut c = vec![10.0f32; 4];
        matmul_into(a.data(), b.data(), &mut c, 2, 2, 2);
        assert_eq!(c, vec![11., 12., 13., 14.]);
    }

    #[test]
    #[should_panic]
    fn dim_mismatch_panics() {
        matmul(&Tensor::zeros(&[2, 3]), &Tensor::zeros(&[4, 2]));
    }
}
