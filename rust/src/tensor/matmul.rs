//! Blocked, threaded f32 matrix multiplication.
//!
//! The kernel computes C[i,:] += A[i,k] * B[k,:] row-major with k-blocking
//! so that the B panel stays in L1/L2 and the inner loop vectorizes (the
//! compiler auto-vectorizes the fused multiply-add over contiguous rows).
//! Rows of C are partitioned across threads — no synchronization needed.

use super::Tensor;
use crate::util::pool::parallel_ranges;

const KB: usize = 256; // k-panel
const MIN_FLOPS_PER_THREAD: usize = 1 << 20;

/// C = A @ B; A [m, k], B [k, n] -> [m, n].
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.rows(), a.cols());
    let (k2, n) = (b.rows(), b.cols());
    assert_eq!(k, k2, "matmul inner dims {k} vs {k2}");
    let mut out = Tensor::zeros(&[m, n]);
    matmul_into(a.data(), b.data(), out.data_mut(), m, k, n);
    out
}

/// C (pre-zeroed or accumulated into) = A @ B on raw slices.
pub fn matmul_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    let flops = 2 * m * k * n;
    let min_rows = (MIN_FLOPS_PER_THREAD / (2 * k * n).max(1)).max(1);
    // Partition rows of C across threads; each thread owns c[lo..hi].
    let c_ptr = SendPtr(c.as_mut_ptr());
    parallel_ranges(m, min_rows, |_, rows| {
        let c = unsafe { std::slice::from_raw_parts_mut(c_ptr.ptr(), m * n) };
        for k0 in (0..k).step_by(KB) {
            let k1 = (k0 + KB).min(k);
            for i in rows.clone() {
                let arow = &a[i * k..(i + 1) * k];
                let crow = &mut c[i * n..(i + 1) * n];
                for kk in k0..k1 {
                    let av = arow[kk];
                    if av == 0.0 {
                        continue;
                    }
                    let brow = &b[kk * n..(kk + 1) * n];
                    axpy(av, brow, crow);
                }
            }
        }
    });
    let _ = flops;
}

/// crow += av * brow  (the vectorizable inner kernel).
#[inline]
fn axpy(av: f32, brow: &[f32], crow: &mut [f32]) {
    let n = crow.len();
    let (bc, bt) = brow.split_at(n - n % 8);
    let (cc, ct) = crow.split_at_mut(n - n % 8);
    for (c8, b8) in cc.chunks_exact_mut(8).zip(bc.chunks_exact(8)) {
        for l in 0..8 {
            c8[l] += av * b8[l];
        }
    }
    for (c1, b1) in ct.iter_mut().zip(bt) {
        *c1 += av * b1;
    }
}

/// G = Aᵀ A for A [r, m] -> [m, m] (the calibration Gram kernel).
/// Symmetric; computes the upper triangle in f64 accumulation and mirrors.
pub fn matmul_at_a(a: &Tensor) -> Tensor {
    let (r, m) = (a.rows(), a.cols());
    let ad = a.data();
    let mut g = Tensor::zeros(&[m, m]);
    let g_ptr = SendPtr(g.data_mut().as_mut_ptr());
    parallel_ranges(m, 8, |_, cols| {
        let gd = unsafe { std::slice::from_raw_parts_mut(g_ptr.ptr(), m * m) };
        for i in cols {
            // row i of G: sum_r a[r,i] * a[r, i..]
            let gi = &mut gd[i * m..(i + 1) * m];
            for row in 0..r {
                let arow = &ad[row * m..(row + 1) * m];
                let ai = arow[i];
                if ai == 0.0 {
                    continue;
                }
                axpy(ai, &arow[i..], &mut gi[i..]);
            }
        }
    });
    // mirror upper -> lower
    for i in 0..m {
        for j in 0..i {
            let v = g.data()[j * m + i];
            g.data_mut()[i * m + j] = v;
        }
    }
    g
}

/// Shared mutable pointer for disjoint-range writes across scoped threads.
/// Callers guarantee each thread writes a disjoint row range.
#[derive(Clone, Copy)]
struct SendPtr(*mut f32);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

impl SendPtr {
    #[inline]
    fn ptr(&self) -> *mut f32 {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn naive(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k, n) = (a.rows(), a.cols(), b.cols());
        let mut c = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0f64;
                for l in 0..k {
                    s += a.at2(i, l) as f64 * b.at2(l, j) as f64;
                }
                c.data_mut()[i * n + j] = s as f32;
            }
        }
        c
    }

    #[test]
    fn matches_naive_various_shapes() {
        let mut rng = Rng::new(1);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 7), (17, 33, 9), (64, 48, 96), (100, 1, 50)] {
            let a = Tensor::new(&[m, k], rng.normal_vec(m * k));
            let b = Tensor::new(&[k, n], rng.normal_vec(k * n));
            let c = matmul(&a, &b);
            let expect = naive(&a, &b);
            assert!(
                c.max_abs_diff(&expect) < 1e-3 * (k as f32).sqrt(),
                "shape ({m},{k},{n})"
            );
        }
    }

    #[test]
    fn at_a_matches_explicit() {
        let mut rng = Rng::new(2);
        for &(r, m) in &[(10, 4), (64, 33), (7, 129)] {
            let a = Tensor::new(&[r, m], rng.normal_vec(r * m));
            let g = matmul_at_a(&a);
            let expect = matmul(&a.transpose2(), &a);
            assert!(g.max_abs_diff(&expect) < 1e-3, "shape ({r},{m})");
            // symmetry is exact by construction
            for i in 0..m {
                for j in 0..m {
                    assert_eq!(g.at2(i, j), g.at2(j, i));
                }
            }
        }
    }

    #[test]
    fn identity() {
        let n = 16;
        let mut eye = Tensor::zeros(&[n, n]);
        for i in 0..n {
            eye.data_mut()[i * n + i] = 1.0;
        }
        let mut rng = Rng::new(3);
        let b = Tensor::new(&[n, 5], rng.normal_vec(n * 5));
        assert_eq!(matmul(&eye, &b), b);
    }

    #[test]
    #[should_panic]
    fn dim_mismatch_panics() {
        matmul(&Tensor::zeros(&[2, 3]), &Tensor::zeros(&[4, 2]));
    }
}
