//! Dense f32 tensor substrate.
//!
//! Deliberately minimal: row-major contiguous storage, 1–4 dims, the ops
//! the model forward passes and quantizers actually need. Matmul is
//! blocked + threaded (see `matmul.rs`); convolution is expressed through
//! `im2col.rs` with patch order (kh, kw, cin) to match the JAX side
//! exactly.

mod im2col;
mod matmul;
pub mod ops;

pub use im2col::{im2col, im2col_grouped};
pub(crate) use matmul::{axpy, matmul_into_packed, pack_b};
pub use matmul::{matmul, matmul_at_a, matmul_into, MR, NR};

use anyhow::{bail, Result};

/// Row-major dense f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} does not match data length {}",
            data.len()
        );
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn full(shape: &[usize], v: f32) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![v; shape.iter().product()] }
    }

    pub fn from_vec(data: Vec<f32>) -> Tensor {
        Tensor { shape: vec![data.len()], data }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { shape: vec![], data: vec![v] }
    }

    // -- accessors ----------------------------------------------------------

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Rows of a 2-D tensor.
    pub fn rows(&self) -> usize {
        assert_eq!(self.ndim(), 2, "rows() needs a 2-D tensor, got {:?}", self.shape);
        self.shape[0]
    }

    /// Columns of a 2-D tensor.
    pub fn cols(&self) -> usize {
        assert_eq!(self.ndim(), 2, "cols() needs a 2-D tensor, got {:?}", self.shape);
        self.shape[1]
    }

    pub fn row(&self, i: usize) -> &[f32] {
        let n = self.cols();
        &self.data[i * n..(i + 1) * n]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let n = self.cols();
        &mut self.data[i * n..(i + 1) * n]
    }

    pub fn at2(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.shape[1] + j]
    }

    // -- shape manipulation ---------------------------------------------

    pub fn reshape(mut self, shape: &[usize]) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            self.data.len(),
            "reshape {:?} -> {shape:?} invalid",
            self.shape
        );
        self.shape = shape.to_vec();
        self
    }

    pub fn try_reshape(self, shape: &[usize]) -> Result<Tensor> {
        if shape.iter().product::<usize>() != self.data.len() {
            bail!("reshape {:?} -> {:?} invalid", self.shape, shape);
        }
        Ok(self.reshape(shape))
    }

    /// 2-D transpose (copying).
    pub fn transpose2(&self) -> Tensor {
        let (m, n) = (self.rows(), self.cols());
        let mut out = vec![0.0f32; m * n];
        // blocked transpose for cache friendliness
        const B: usize = 32;
        for i0 in (0..m).step_by(B) {
            for j0 in (0..n).step_by(B) {
                for i in i0..(i0 + B).min(m) {
                    for j in j0..(j0 + B).min(n) {
                        out[j * m + i] = self.data[i * n + j];
                    }
                }
            }
        }
        Tensor::new(&[n, m], out)
    }

    /// Extract column j of a 2-D tensor.
    pub fn col(&self, j: usize) -> Vec<f32> {
        let (m, n) = (self.rows(), self.cols());
        (0..m).map(|i| self.data[i * n + j]).collect()
    }

    // -- reductions & norms -----------------------------------------------

    pub fn frob_norm_sq(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }

    pub fn min(&self) -> f32 {
        self.data.iter().copied().fold(f32::INFINITY, f32::min)
    }

    pub fn max(&self) -> f32 {
        self.data.iter().copied().fold(f32::NEG_INFINITY, f32::max)
    }

    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |a, &x| a.max(x.abs()))
    }

    /// Per-column min/max of a 2-D tensor: returns (mins, maxs).
    pub fn col_min_max(&self) -> (Vec<f32>, Vec<f32>) {
        let (m, n) = (self.rows(), self.cols());
        let mut mins = vec![f32::INFINITY; n];
        let mut maxs = vec![f32::NEG_INFINITY; n];
        for i in 0..m {
            let row = &self.data[i * n..(i + 1) * n];
            for j in 0..n {
                mins[j] = mins[j].min(row[j]);
                maxs[j] = maxs[j].max(row[j]);
            }
        }
        (mins, maxs)
    }

    /// Per-column infinity norm of a 2-D tensor.
    pub fn col_inf_norm(&self) -> Vec<f32> {
        let (mins, maxs) = self.col_min_max();
        mins.iter().zip(&maxs).map(|(a, b)| a.abs().max(b.abs())).collect()
    }

    // -- elementwise ------------------------------------------------------

    pub fn map(mut self, f: impl Fn(f32) -> f32) -> Tensor {
        for x in &mut self.data {
            *x = f(*x);
        }
        self
    }

    pub fn add_assign(&mut self, other: &Tensor) {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    pub fn sub(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape);
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Tensor { shape: self.shape.clone(), data }
    }

    pub fn scale(mut self, s: f32) -> Tensor {
        for x in &mut self.data {
            *x *= s;
        }
        self
    }

    /// Max absolute elementwise difference (for parity tests).
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .fold(0.0f32, |acc, (a, b)| acc.max((a - b).abs()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_index() {
        let t = Tensor::new(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.rows(), 2);
        assert_eq!(t.cols(), 3);
        assert_eq!(t.at2(1, 2), 6.0);
        assert_eq!(t.row(1), &[4., 5., 6.]);
        assert_eq!(t.col(1), vec![2., 5.]);
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        Tensor::new(&[2, 2], vec![1.0; 3]);
    }

    #[test]
    fn transpose_roundtrip() {
        let t = Tensor::new(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let tt = t.transpose2();
        assert_eq!(tt.shape(), &[3, 2]);
        assert_eq!(tt.at2(2, 1), 6.0);
        assert_eq!(tt.transpose2(), t);
    }

    #[test]
    fn col_min_max() {
        let t = Tensor::new(&[2, 2], vec![1., -5., 3., 2.]);
        let (mins, maxs) = t.col_min_max();
        assert_eq!(mins, vec![1., -5.]);
        assert_eq!(maxs, vec![3., 2.]);
        assert_eq!(t.col_inf_norm(), vec![3., 5.]);
    }

    #[test]
    fn elementwise() {
        let a = Tensor::new(&[2], vec![1., 2.]);
        let b = Tensor::new(&[2], vec![0.5, 1.0]);
        assert_eq!(a.sub(&b).data(), &[0.5, 1.0]);
        assert_eq!(a.clone().scale(2.0).data(), &[2., 4.]);
        assert_eq!(a.max_abs_diff(&b), 1.0);
        let mut c = a.clone();
        c.add_assign(&b);
        assert_eq!(c.data(), &[1.5, 3.0]);
    }

    #[test]
    fn reshape_checks() {
        let t = Tensor::zeros(&[4, 2]);
        assert_eq!(t.clone().reshape(&[2, 4]).shape(), &[2, 4]);
        assert!(t.try_reshape(&[3, 3]).is_err());
    }
}
