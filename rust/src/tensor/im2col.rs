//! im2col: convolution -> matmul reduction, mirroring
//! python/compile/nets/common.py::im2col exactly.
//!
//! Input is NHWC; the patch axis is ordered (kh, kw, cin). This is the
//! identity that lets the paper treat "a convolutional layer ... as a
//! linear layer" for layer-wise PTQ: the conv weight [k*k*cin, cout]
//! multiplies the im2col matrix [b*oh*ow, k*k*cin].

use super::Tensor;

/// x [b, h, w, c] -> ([b*oh*ow, k*k*c], oh, ow) with patch order (kh, kw, c).
pub fn im2col(x: &Tensor, k: usize, stride: usize, pad: usize) -> (Tensor, usize, usize) {
    assert_eq!(x.ndim(), 4, "im2col expects NHWC, got {:?}", x.shape());
    let (b, h, w, c) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    let oh = (h + 2 * pad - k) / stride + 1;
    let ow = (w + 2 * pad - k) / stride + 1;
    let m = k * k * c;
    let xd = x.data();
    let mut out = vec![0.0f32; b * oh * ow * m];
    for bi in 0..b {
        let xb = &xd[bi * h * w * c..(bi + 1) * h * w * c];
        for oy in 0..oh {
            for ox in 0..ow {
                let row = &mut out[((bi * oh + oy) * ow + ox) * m..((bi * oh + oy) * ow + ox + 1) * m];
                for ki in 0..k {
                    let iy = (oy * stride + ki) as isize - pad as isize;
                    for kj in 0..k {
                        let ix = (ox * stride + kj) as isize - pad as isize;
                        let dst = &mut row[(ki * k + kj) * c..(ki * k + kj + 1) * c];
                        if iy >= 0 && (iy as usize) < h && ix >= 0 && (ix as usize) < w {
                            let src = &xb[(iy as usize * w + ix as usize) * c..][..c];
                            dst.copy_from_slice(src);
                        }
                        // else: zero padding (already zeroed)
                    }
                }
            }
        }
    }
    (Tensor::new(&[b * oh * ow, m], out), oh, ow)
}

/// Grouped (depthwise) im2col: x [b,h,w,c] -> [rows, c, k*k] flattened as a
/// 3-D tensor, matching nets/common.py::dwconv2d (x3d layout [rows, c, kk]).
///
/// Fills the grouped layout directly — each pixel read scatters its `c`
/// channels to stride-`kk` positions — instead of materializing the
/// dense (kh, kw, c) patch matrix first and regrouping it, which
/// doubled the working set of every depthwise layer. Parity with the
/// regrouped dense path is property-tested (rust/tests/prop_quant.rs).
pub fn im2col_grouped(x: &Tensor, k: usize, stride: usize, pad: usize) -> (Tensor, usize, usize) {
    assert_eq!(x.ndim(), 4, "im2col_grouped expects NHWC, got {:?}", x.shape());
    let (b, h, w, c) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    let oh = (h + 2 * pad - k) / stride + 1;
    let ow = (w + 2 * pad - k) / stride + 1;
    let kk = k * k;
    let xd = x.data();
    let mut out = vec![0.0f32; b * oh * ow * c * kk];
    for bi in 0..b {
        let xb = &xd[bi * h * w * c..(bi + 1) * h * w * c];
        for oy in 0..oh {
            for ox in 0..ow {
                let r = (bi * oh + oy) * ow + ox;
                let row = &mut out[r * c * kk..(r + 1) * c * kk];
                for ki in 0..k {
                    let iy = (oy * stride + ki) as isize - pad as isize;
                    if iy < 0 || iy as usize >= h {
                        continue; // zero padding (already zeroed)
                    }
                    for kj in 0..k {
                        let ix = (ox * stride + kj) as isize - pad as isize;
                        if ix < 0 || ix as usize >= w {
                            continue;
                        }
                        let src = &xb[(iy as usize * w + ix as usize) * c..][..c];
                        let p = ki * k + kj;
                        for (ch, &v) in src.iter().enumerate() {
                            row[ch * kk + p] = v;
                        }
                    }
                }
            }
        }
    }
    (Tensor::new(&[b * oh * ow, c, kk], out), oh, ow)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_1x1() {
        // k=1 stride=1 pad=0: im2col is just a reshape
        let x = Tensor::new(&[1, 2, 2, 3], (0..12).map(|i| i as f32).collect());
        let (cols, oh, ow) = im2col(&x, 1, 1, 0);
        assert_eq!((oh, ow), (2, 2));
        assert_eq!(cols.shape(), &[4, 3]);
        assert_eq!(cols.data(), x.data());
    }

    #[test]
    fn known_3x3_padded() {
        // 3x3 single-channel image, k=3 pad=1: center patch = whole image
        let x = Tensor::new(&[1, 3, 3, 1], (1..=9).map(|i| i as f32).collect());
        let (cols, oh, ow) = im2col(&x, 3, 1, 1);
        assert_eq!((oh, ow), (3, 3));
        assert_eq!(cols.shape(), &[9, 9]);
        // center output position (1,1) sees the full image in (kh,kw) order
        assert_eq!(cols.row(4), &[1., 2., 3., 4., 5., 6., 7., 8., 9.]);
        // top-left position (0,0): first row/col padded with zeros
        assert_eq!(cols.row(0), &[0., 0., 0., 0., 1., 2., 0., 4., 5.]);
    }

    #[test]
    fn stride_2() {
        let x = Tensor::new(&[1, 4, 4, 1], (0..16).map(|i| i as f32).collect());
        let (cols, oh, ow) = im2col(&x, 2, 2, 0);
        assert_eq!((oh, ow), (2, 2));
        // patch at (0,0): pixels (0,0),(0,1),(1,0),(1,1) = 0,1,4,5
        assert_eq!(cols.row(0), &[0., 1., 4., 5.]);
        // patch at (1,1): pixels (2,2),(2,3),(3,2),(3,3) = 10,11,14,15
        assert_eq!(cols.row(3), &[10., 11., 14., 15.]);
    }

    #[test]
    fn grouped_layout() {
        let x = Tensor::new(&[1, 2, 2, 2], (0..8).map(|i| i as f32).collect());
        let (g, oh, ow) = im2col_grouped(&x, 1, 1, 0);
        assert_eq!((oh, ow), (2, 2));
        assert_eq!(g.shape(), &[4, 2, 1]);
        // row 0 = pixel (0,0): channels (0, 1)
        assert_eq!(&g.data()[0..2], &[0., 1.]);
    }

    #[test]
    fn batch_independence() {
        let x1 = Tensor::new(&[1, 3, 3, 1], (0..9).map(|i| i as f32).collect());
        let x2 = Tensor::new(&[1, 3, 3, 1], (9..18).map(|i| i as f32).collect());
        let mut both = x1.data().to_vec();
        both.extend_from_slice(x2.data());
        let xb = Tensor::new(&[2, 3, 3, 1], both);
        let (c1, _, _) = im2col(&x1, 3, 1, 1);
        let (cb, _, _) = im2col(&xb, 3, 1, 1);
        assert_eq!(&cb.data()[..c1.len()], c1.data());
    }
}
