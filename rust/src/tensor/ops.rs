//! Neural-net forward ops mirroring python/compile/nets/common.py.
//!
//! Each op is an exact operational mirror of its JAX counterpart (same
//! GELU closed form, same LayerNorm epsilon, same softmax shift) so the
//! native forward and the PJRT forward agree to float tolerance.

use super::Tensor;

/// tanh-approximate GELU (same constant as nets/common.py::gelu).
pub fn gelu(x: f32) -> f32 {
    const C: f32 = 0.7978845608028654; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

pub fn gelu_inplace(t: &mut Tensor) {
    for x in t.data_mut() {
        *x = gelu(*x);
    }
}

pub fn relu_inplace(t: &mut Tensor) {
    for x in t.data_mut() {
        *x = x.max(0.0);
    }
}

/// LayerNorm over the last axis with affine (gamma, beta); eps = 1e-5.
pub fn layer_norm(t: &mut Tensor, gamma: &[f32], beta: &[f32]) {
    let d = *t.shape().last().expect("layer_norm needs >=1 dim");
    assert_eq!(gamma.len(), d);
    assert_eq!(beta.len(), d);
    const EPS: f32 = 1e-5;
    for row in t.data_mut().chunks_exact_mut(d) {
        let mean = row.iter().sum::<f32>() / d as f32;
        let var = row.iter().map(|&x| (x - mean) * (x - mean)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + EPS).sqrt();
        for (x, (g, b)) in row.iter_mut().zip(gamma.iter().zip(beta)) {
            *x = (*x - mean) * inv * g + b;
        }
    }
}

/// Softmax over the last axis (shift-stabilized, matching nets/common.py).
pub fn softmax_lastdim(t: &mut Tensor) {
    let d = *t.shape().last().expect("softmax needs >=1 dim");
    for row in t.data_mut().chunks_exact_mut(d) {
        let mx = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for x in row.iter_mut() {
            *x = (*x - mx).exp();
            sum += *x;
        }
        let inv = 1.0 / sum;
        for x in row.iter_mut() {
            *x *= inv;
        }
    }
}

/// Add a bias row vector to every row of a 2-D tensor.
pub fn add_bias(t: &mut Tensor, bias: &[f32]) {
    let n = t.cols();
    assert_eq!(bias.len(), n);
    for row in t.data_mut().chunks_exact_mut(n) {
        for (x, b) in row.iter_mut().zip(bias) {
            *x += b;
        }
    }
}

/// Mean over axis 1 of [b, t, d] -> [b, d].
pub fn mean_axis1(t: &Tensor) -> Tensor {
    assert_eq!(t.ndim(), 3);
    let (b, tt, d) = (t.shape()[0], t.shape()[1], t.shape()[2]);
    let mut out = Tensor::zeros(&[b, d]);
    let inv = 1.0 / tt as f32;
    for bi in 0..b {
        let dst = &mut out.data_mut()[bi * d..(bi + 1) * d];
        for ti in 0..tt {
            let src = &t.data()[(bi * tt + ti) * d..(bi * tt + ti + 1) * d];
            for (o, s) in dst.iter_mut().zip(src) {
                *o += s * inv;
            }
        }
    }
    out
}

/// Global average pool over spatial dims of NHWC [b, h, w, c] -> [b, c].
pub fn global_avg_pool(t: &Tensor) -> Tensor {
    assert_eq!(t.ndim(), 4);
    let (b, h, w, c) = (t.shape()[0], t.shape()[1], t.shape()[2], t.shape()[3]);
    let inv = 1.0 / (h * w) as f32;
    let mut out = Tensor::zeros(&[b, c]);
    for bi in 0..b {
        let dst = &mut out.data_mut()[bi * c..(bi + 1) * c];
        for p in 0..h * w {
            let src = &t.data()[(bi * h * w + p) * c..(bi * h * w + p + 1) * c];
            for (o, s) in dst.iter_mut().zip(src) {
                *o += s * inv;
            }
        }
    }
    out
}

/// 2x2 average pool, stride 2, NHWC (matching nets/cnn.py::avgpool2).
pub fn avg_pool2(t: &Tensor) -> Tensor {
    assert_eq!(t.ndim(), 4);
    let (b, h, w, c) = (t.shape()[0], t.shape()[1], t.shape()[2], t.shape()[3]);
    let (oh, ow) = (h / 2, w / 2);
    let mut out = Tensor::zeros(&[b, oh, ow, c]);
    for bi in 0..b {
        for oy in 0..oh {
            for ox in 0..ow {
                let dst_idx = ((bi * oh + oy) * ow + ox) * c;
                for (dy, dx) in [(0, 0), (0, 1), (1, 0), (1, 1)] {
                    let src_idx = ((bi * h + 2 * oy + dy) * w + 2 * ox + dx) * c;
                    for ch in 0..c {
                        out.data_mut()[dst_idx + ch] += 0.25 * t.data()[src_idx + ch];
                    }
                }
            }
        }
    }
    out
}

/// Strided spatial subsample x[:, ::s, ::s, :] (resnet shortcut path).
pub fn stride_slice(t: &Tensor, s: usize) -> Tensor {
    assert_eq!(t.ndim(), 4);
    let (b, h, w, c) = (t.shape()[0], t.shape()[1], t.shape()[2], t.shape()[3]);
    let (oh, ow) = (h.div_ceil(s), w.div_ceil(s));
    let mut out = Tensor::zeros(&[b, oh, ow, c]);
    for bi in 0..b {
        for oy in 0..oh {
            for ox in 0..ow {
                let src = &t.data()[((bi * h + oy * s) * w + ox * s) * c..][..c];
                let dst = &mut out.data_mut()[((bi * oh + oy) * ow + ox) * c..][..c];
                dst.copy_from_slice(src);
            }
        }
    }
    out
}

/// Cyclic roll of the [g, g] token grid of [b, g*g, d] by (-s, -s)
/// (Swin shifted windows; matches jnp.roll with negative shift).
pub fn shift_tokens(t: &Tensor, g: usize, s: isize) -> Tensor {
    assert_eq!(t.ndim(), 3);
    let (b, tok, d) = (t.shape()[0], t.shape()[1], t.shape()[2]);
    assert_eq!(tok, g * g);
    let mut out = Tensor::zeros(&[b, tok, d]);
    let sm = s.rem_euclid(g as isize) as usize;
    for bi in 0..b {
        for y in 0..g {
            for x in 0..g {
                // jnp.roll(xi, (-s, -s)): out[y, x] = in[(y + s) mod g, (x + s) mod g]
                let sy = (y + sm) % g;
                let sx = (x + sm) % g;
                let src = &t.data()[((bi * tok) + sy * g + sx) * d..][..d];
                let dst = &mut out.data_mut()[((bi * tok) + y * g + x) * d..][..d];
                dst.copy_from_slice(src);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gelu_known_values() {
        assert!((gelu(0.0)).abs() < 1e-7);
        assert!((gelu(1.0) - 0.841192).abs() < 1e-4);
        assert!((gelu(-1.0) + 0.158808).abs() < 1e-4);
        // large |x| saturates to x or 0
        assert!((gelu(10.0) - 10.0).abs() < 1e-4);
        assert!(gelu(-10.0).abs() < 1e-4);
    }

    #[test]
    fn layer_norm_normalizes() {
        let mut t = Tensor::new(&[2, 4], vec![1., 2., 3., 4., -1., 0., 1., 2.]);
        let g = vec![1.0; 4];
        let b = vec![0.0; 4];
        layer_norm(&mut t, &g, &b);
        for row in t.data().chunks(4) {
            let mean: f32 = row.iter().sum::<f32>() / 4.0;
            let var: f32 = row.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / 4.0;
            assert!(mean.abs() < 1e-5);
            assert!((var - 1.0).abs() < 1e-3);
        }
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut t = Tensor::new(&[2, 3], vec![1., 2., 3., -10., 0., 10.]);
        softmax_lastdim(&mut t);
        for row in t.data().chunks(3) {
            assert!((row.iter().sum::<f32>() - 1.0).abs() < 1e-5);
            assert!(row.iter().all(|&p| p >= 0.0));
        }
    }

    #[test]
    fn pools() {
        let t = Tensor::new(&[1, 2, 2, 1], vec![1., 2., 3., 4.]);
        assert_eq!(avg_pool2(&t).data(), &[2.5]);
        assert_eq!(global_avg_pool(&t).data(), &[2.5]);
    }

    #[test]
    fn mean_axis1_works() {
        let t = Tensor::new(&[1, 2, 2], vec![1., 2., 3., 4.]);
        assert_eq!(mean_axis1(&t).data(), &[2., 3.]);
    }

    #[test]
    fn stride_slice_works() {
        let t = Tensor::new(&[1, 4, 4, 1], (0..16).map(|i| i as f32).collect());
        let s = stride_slice(&t, 2);
        assert_eq!(s.shape(), &[1, 2, 2, 1]);
        assert_eq!(s.data(), &[0., 2., 8., 10.]);
    }

    #[test]
    fn shift_roundtrip() {
        let g = 4;
        let t = Tensor::new(&[1, 16, 1], (0..16).map(|i| i as f32).collect());
        let shifted = shift_tokens(&t, g, 1);
        let back = shift_tokens(&shifted, g, -1);
        assert_eq!(back, t);
        // out[0,0] = in[1,1] = 5
        assert_eq!(shifted.data()[0], 5.0);
    }
}
