//! Coordinate update orders (Sec. 3.3).
//!
//! COMQ's greedy rule updates the most "important" coordinates first:
//! importance of row i for column j is ‖x_i‖·|w_ij| (the magnitude of the
//! rank-1 term w_ij·x_i in the column's reconstruction). Three variants:
//!
//! * `Cyclic`          — plain index order (QuantEase-style; the paper's †)
//! * `GreedyShared`    — one order shared by every column, score
//!                       ‖x_i‖·mean_j|w_ij| (the paper's vectorized form;
//!                       also what the Pallas kernel uses via permutation)
//! * `GreedyPerColumn` — each column sorts independently (strict rule)

use crate::tensor::Tensor;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OrderKind {
    Cyclic,
    GreedyShared,
    GreedyPerColumn,
}

impl OrderKind {
    pub fn parse(s: &str) -> Option<OrderKind> {
        match s {
            "cyclic" => Some(OrderKind::Cyclic),
            "greedy" | "greedy-per-column" => Some(OrderKind::GreedyPerColumn),
            "greedy-shared" => Some(OrderKind::GreedyShared),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            OrderKind::Cyclic => "cyclic",
            OrderKind::GreedyShared => "greedy-shared",
            OrderKind::GreedyPerColumn => "greedy",
        }
    }
}

/// Stable argsort descending into a reusable buffer (no per-call alloc
/// once `out` has grown to capacity).
pub fn argsort_desc_into(scores: &[f32], out: &mut Vec<u32>) {
    out.clear();
    out.extend(0..scores.len() as u32);
    out.sort_by(|&a, &b| {
        scores[b as usize]
            .partial_cmp(&scores[a as usize])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
}

/// Row-update order for column j. `diag` = diag(G) (= ‖x_i‖²).
pub fn order_for_column(kind: OrderKind, diag: &[f32], w: &Tensor, j: usize) -> Vec<u32> {
    let mut out = Vec::new();
    let mut scores = Vec::new();
    order_for_column_into(kind, diag, w, j, &mut scores, &mut out);
    out
}

/// Scratch-reusing variant of [`order_for_column`]: identical result,
/// but `scores`/`out` are caller-owned so the per-column-per-sweep
/// allocations of the hot loop disappear. Note the greedy scores depend
/// only on diag(G) and |W| — both sweep-invariant — so callers can also
/// compute orders once per layer and reuse them across sweeps (the
/// workspace engine does; see quant/workspace.rs).
pub fn order_for_column_into(
    kind: OrderKind,
    diag: &[f32],
    w: &Tensor,
    j: usize,
    scores: &mut Vec<f32>,
    out: &mut Vec<u32>,
) {
    let m = w.rows();
    match kind {
        OrderKind::Cyclic => {
            out.clear();
            out.extend(0..m as u32);
        }
        OrderKind::GreedyPerColumn => {
            scores.clear();
            scores.extend((0..m).map(|i| diag[i].max(0.0).sqrt() * w.at2(i, j).abs()));
            argsort_desc_into(scores, out);
        }
        OrderKind::GreedyShared => shared_order_into(diag, w, scores, out),
    }
}

/// The shared greedy order: score_i = ‖x_i‖ · mean_j |w_ij|.
pub fn shared_order(diag: &[f32], w: &Tensor) -> Vec<u32> {
    let mut scores = Vec::new();
    let mut out = Vec::new();
    shared_order_into(diag, w, &mut scores, &mut out);
    out
}

/// Scratch-reusing variant of [`shared_order`] (the grouped-Gram hot
/// path recomputes the "shared" order per column because each column
/// has its own diag).
pub fn shared_order_into(diag: &[f32], w: &Tensor, scores: &mut Vec<f32>, out: &mut Vec<u32>) {
    let (m, n) = (w.rows(), w.cols());
    scores.clear();
    scores.extend((0..m).map(|i| {
        let mean_abs = w.row(i).iter().map(|v| v.abs()).sum::<f32>() / n as f32;
        diag[i].max(0.0).sqrt() * mean_abs
    }));
    argsort_desc_into(scores, out);
}

/// Inverse permutation: out[perm[i]] = i.
pub fn invert(perm: &[u32]) -> Vec<u32> {
    let mut inv = vec![0u32; perm.len()];
    for (i, &p) in perm.iter().enumerate() {
        inv[p as usize] = i as u32;
    }
    inv
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cyclic_is_identity() {
        let w = Tensor::zeros(&[4, 2]);
        let o = order_for_column(OrderKind::Cyclic, &[1.0; 4], &w, 0);
        assert_eq!(o, vec![0, 1, 2, 3]);
    }

    #[test]
    fn greedy_sorts_by_magnitude() {
        // column 0 weights: [1, 3, 2]; uniform diag -> order 1, 2, 0
        let w = Tensor::new(&[3, 1], vec![1.0, -3.0, 2.0]);
        let o = order_for_column(OrderKind::GreedyPerColumn, &[1.0; 3], &w, 0);
        assert_eq!(o, vec![1, 2, 0]);
    }

    #[test]
    fn greedy_weighs_feature_norm() {
        // same |w| everywhere; diag differs -> order by diag
        let w = Tensor::new(&[3, 1], vec![1.0, 1.0, 1.0]);
        let o = order_for_column(OrderKind::GreedyPerColumn, &[1.0, 9.0, 4.0], &w, 0);
        assert_eq!(o, vec![1, 2, 0]);
    }

    #[test]
    fn orders_are_permutations() {
        let w = Tensor::new(&[5, 3], (0..15).map(|i| ((i * 7) % 5) as f32 - 2.0).collect());
        let diag = [0.5, 2.0, 0.0, 1.0, 3.0];
        for kind in [OrderKind::Cyclic, OrderKind::GreedyShared, OrderKind::GreedyPerColumn] {
            for j in 0..3 {
                let mut o = order_for_column(kind, &diag, &w, j);
                o.sort();
                assert_eq!(o, vec![0, 1, 2, 3, 4], "{kind:?} col {j}");
            }
        }
    }

    #[test]
    fn stable_on_ties() {
        let w = Tensor::new(&[3, 1], vec![1.0, 1.0, 1.0]);
        let o = order_for_column(OrderKind::GreedyPerColumn, &[1.0; 3], &w, 0);
        assert_eq!(o, vec![0, 1, 2]); // ties keep index order
    }

    #[test]
    fn into_variants_match_allocating_api() {
        let w = Tensor::new(&[6, 3], (0..18).map(|i| ((i * 5) % 7) as f32 - 3.0).collect());
        let diag = [2.0, 0.5, 0.0, 1.5, 3.0, 0.25];
        let mut scores = Vec::new();
        let mut out = Vec::new();
        for kind in [OrderKind::Cyclic, OrderKind::GreedyShared, OrderKind::GreedyPerColumn] {
            for j in 0..3 {
                order_for_column_into(kind, &diag, &w, j, &mut scores, &mut out);
                assert_eq!(out, order_for_column(kind, &diag, &w, j), "{kind:?} col {j}");
            }
        }
    }

    #[test]
    fn invert_roundtrip() {
        let perm = vec![2u32, 0, 3, 1];
        let inv = invert(&perm);
        for (i, &p) in perm.iter().enumerate() {
            assert_eq!(inv[p as usize], i as u32);
        }
    }
}
