//! AdaRound-lite: adaptive rounding fitted *without* gradients.
//!
//! AdaRound (Nagel et al. 2020) learns a per-weight up/down rounding mask
//! by SGD on the layer reconstruction error. The published method needs
//! backprop; this baseline keeps the search space (q_i ∈ {⌊w/δ⌋, ⌈w/δ⌉},
//! scale fixed at init) but fits the mask by the same closed-form
//! coordinate descent machinery as COMQ — i.e. it is COMQ restricted to
//! the two adjacent grid points with a frozen δ. The gap between this and
//! full COMQ in the tables isolates the value of (a) the wider code range
//! and (b) the learned scale.

use crate::tensor::Tensor;
use crate::util::pool::parallel_ranges;

use super::comq::EPS_DIAG;
use super::gram::GramSet;
use super::grid::{init_grid, LayerQuant, QuantConfig};

pub fn adaround_lite(gram: &GramSet, w: &Tensor, cfg: &QuantConfig) -> LayerQuant {
    let (m, n) = (w.rows(), w.cols());
    let (delta, zero) = init_grid(w, cfg);
    let levels = cfg.levels();
    let mut q = Tensor::zeros(&[m, n]);
    // init at floor
    for i in 0..m {
        let wrow = w.row(i);
        let qrow = q.row_mut(i);
        for j in 0..n {
            qrow[j] = (wrow[j] / delta[j]).floor().clamp(zero[j], zero[j] + levels);
        }
    }
    let q_ptr = QPtr(q.data_mut().as_mut_ptr());
    parallel_ranges(n, 4, |_, cols| {
        let mut p = vec![0.0f32; m];
        let mut wcol = vec![0.0f32; m];
        let mut qcol = vec![0.0f32; m];
        for j in cols {
            let g = gram.for_col(j);
            let dj = delta[j];
            let zj = zero[j];
            let qd = unsafe { std::slice::from_raw_parts_mut(q_ptr.ptr(), m * n) };
            for i in 0..m {
                wcol[i] = w.at2(i, j);
                qcol[i] = qd[i * n + j];
            }
            // p = G (w − δ q)
            for i in 0..m {
                let mut s = 0.0f32;
                let grow = g.row(i);
                for t in 0..m {
                    s += grow[t] * (wcol[t] - dj * qcol[t]);
                }
                p[i] = s;
            }
            for _sweep in 0..cfg.iters {
                for i in 0..m {
                    let gii = g.at2(i, i);
                    if gii <= EPS_DIAG {
                        continue;
                    }
                    let lo = (wcol[i] / dj).floor().clamp(zj, zj + levels);
                    let hi = (lo + 1.0).min(zj + levels);
                    let r_old = wcol[i] - dj * qcol[i];
                    // continuous optimum, then snap to the nearer of {lo, hi}
                    let cont = (p[i] - gii * r_old + gii * wcol[i]) / gii / dj;
                    let q_new = if (cont - lo).abs() <= (cont - hi).abs() { lo } else { hi };
                    if q_new != qcol[i] {
                        let dr = (wcol[i] - dj * q_new) - r_old;
                        let grow = g.row(i);
                        for (pt, gt) in p.iter_mut().zip(grow) {
                            *pt += gt * dr;
                        }
                        qcol[i] = q_new;
                    }
                }
            }
            for i in 0..m {
                qd[i * n + j] = qcol[i];
            }
        }
    });
    LayerQuant { q, delta, zero }
}

struct QPtr(*mut f32);
unsafe impl Send for QPtr {}
unsafe impl Sync for QPtr {}
impl QPtr {
    #[inline]
    fn ptr(&self) -> *mut f32 {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn::rtn;
    use crate::quant::{comq_gram, OrderKind, Scheme};
    use crate::util::Rng;

    fn cfg(bits: u32) -> QuantConfig {
        QuantConfig {
            bits,
            scheme: Scheme::PerChannel,
            order: OrderKind::Cyclic,
            iters: 3,
            lam: 1.0,
        }
    }

    fn setup(seed: u64) -> (Tensor, GramSet) {
        let mut rng = Rng::new(seed);
        let (b, m, n) = (96, 24, 12);
        let x = Tensor::new(&[b, m], rng.normal_vec(b * m));
        let w = Tensor::new(&[m, n], rng.normal_vec(m * n)).scale(0.4);
        (w, GramSet::from_features(&x))
    }

    #[test]
    fn beats_rtn() {
        let (w, g) = setup(60);
        for bits in [3u32, 4] {
            let c = cfg(bits);
            let e_ada = g.recon_error(&w, &adaround_lite(&g, &w, &c).dequant());
            let e_rtn = g.recon_error(&w, &rtn(&w, &c).dequant());
            assert!(e_ada < e_rtn, "bits={bits}: ada {e_ada} vs rtn {e_rtn}");
        }
    }

    #[test]
    fn comq_at_least_as_good() {
        // COMQ searches the full range with learned δ; AdaRound-lite can't win
        let mut tot_a = 0.0;
        let mut tot_c = 0.0;
        for seed in 0..5 {
            let (w, g) = setup(70 + seed);
            let c = cfg(2);
            tot_a += g.recon_error(&w, &adaround_lite(&g, &w, &c).dequant());
            tot_c += g.recon_error(&w, &comq_gram(&g, &w, &c).dequant());
        }
        assert!(tot_c <= tot_a * 1.05, "comq {tot_c} vs adaround {tot_a}");
    }

    #[test]
    fn stays_adjacent_to_rtn_grid() {
        // every code is floor or ceil of w/δ (clamped)
        let (w, g) = setup(80);
        let c = cfg(4);
        let lq = adaround_lite(&g, &w, &c);
        assert!(lq.codes_feasible(4));
        for i in 0..w.rows() {
            for j in 0..w.cols() {
                let raw = w.at2(i, j) / lq.delta[j];
                let q = lq.q.at2(i, j);
                let lo = raw.floor().clamp(lq.zero[j], lq.zero[j] + 15.0);
                let hi = (lo + 1.0).min(lq.zero[j] + 15.0);
                assert!(q == lo || q == hi, "({i},{j}): q={q} raw={raw}");
            }
        }
    }
}
