//! OBQ / GPTQ-style baseline: Optimal Brain Quantization.
//!
//! Quantizes coordinates one at a time and *compensates the remaining
//! full-precision weights* using the inverse Hessian of the layer
//! objective (H = 2G, the 2 cancels). This is the strongest
//! backprop-free comparator in the paper (Frantar & Alistarh 2022;
//! "OPTQ/GPTQ" for LLMs) — more powerful per step than COMQ but needs
//! H⁻¹ (O(m³) setup + O(m²) per coordinate with dense updates).
//!
//! Implementation: classic OBS recursion. For row i (in order):
//! ```text
//!     q_i   = quant(w_i)
//!     e     = (w_i − δ q_i) / [H⁻¹]_ii            (per column)
//!     w_t  −= e · [H⁻¹]_{t,i}   for remaining t
//!     H⁻¹  ← H⁻¹ − H⁻¹[:,i] H⁻¹[i,:] / [H⁻¹]_ii   (row/col i removed)
//! ```
//!
//! All columns share H so the row loop vectorizes across columns, same
//! as COMQ's row-wise update.

use crate::tensor::Tensor;

use super::gram::GramSet;
use super::grid::{init_grid, qround, LayerQuant, QuantConfig};
use super::linalg::{damped, invert_spd};

/// Relative damping (GPTQ uses 0.01 of mean diagonal).
pub const DAMP: f64 = 0.01;

pub fn obq(gram: &GramSet, w: &Tensor, cfg: &QuantConfig) -> LayerQuant {
    match gram {
        GramSet::Shared(g) => obq_shared(g, w, cfg),
        GramSet::Grouped(gs) => obq_grouped(gs, w, cfg),
    }
}

fn obq_shared(g: &Tensor, w: &Tensor, cfg: &QuantConfig) -> LayerQuant {
    let (m, n) = (w.rows(), w.cols());
    let (delta, zero) = init_grid(w, cfg);
    let levels = cfg.levels();
    // H⁻¹ with damping; fall back to RTN if inversion fails outright
    let hinv = match invert_spd(&damped(g, DAMP)) {
        Ok(h) => h,
        Err(_) => return super::rtn::rtn(w, cfg),
    };
    let mut hinv = hinv;
    let mut wk = w.clone(); // working (compensated) weights
    let mut q = Tensor::zeros(&[m, n]);
    for i in 0..m {
        let dii = hinv.at2(i, i).max(1e-12);
        // quantize row i across all columns
        let mut err = vec![0.0f32; n];
        {
            let wrow = wk.row(i);
            let qrow = q.row_mut(i);
            for j in 0..n {
                qrow[j] = qround(wrow[j] / delta[j], zero[j], levels);
                err[j] = (wrow[j] - delta[j] * qrow[j]) / dii;
            }
        }
        // compensate remaining rows: w_t -= hinv[t,i] * err
        for t in (i + 1)..m {
            let h_ti = hinv.at2(t, i);
            if h_ti == 0.0 {
                continue;
            }
            let wrow = wk.row_mut(t);
            for j in 0..n {
                wrow[j] -= h_ti * err[j];
            }
        }
        // rank-1 downdate of H⁻¹ (only the trailing block matters)
        let col_i: Vec<f32> = (i..m).map(|t| hinv.at2(t, i)).collect();
        let inv_dii = 1.0 / dii;
        for t in (i + 1)..m {
            let c_t = col_i[t - i] * inv_dii;
            if c_t == 0.0 {
                continue;
            }
            let hrow = hinv.row_mut(t);
            for s in (i + 1)..m {
                hrow[s] -= c_t * col_i[s - i];
            }
        }
    }
    LayerQuant { q, delta, zero }
}

fn obq_grouped(gs: &[Tensor], w: &Tensor, cfg: &QuantConfig) -> LayerQuant {
    // every column has its own (small) Hessian; run OBQ per column
    let (m, n) = (w.rows(), w.cols());
    let (delta, zero) = init_grid(w, cfg);
    let levels = cfg.levels();
    let mut q = Tensor::zeros(&[m, n]);
    for j in 0..n {
        let hinv = match invert_spd(&damped(&gs[j], DAMP)) {
            Ok(h) => h,
            Err(_) => {
                for i in 0..m {
                    q.data_mut()[i * n + j] = qround(w.at2(i, j) / delta[j], zero[j], levels);
                }
                continue;
            }
        };
        let mut hinv = hinv;
        let mut wcol: Vec<f32> = (0..m).map(|i| w.at2(i, j)).collect();
        for i in 0..m {
            let dii = hinv.at2(i, i).max(1e-12);
            let qv = qround(wcol[i] / delta[j], zero[j], levels);
            q.data_mut()[i * n + j] = qv;
            let e = (wcol[i] - delta[j] * qv) / dii;
            for t in (i + 1)..m {
                wcol[t] -= hinv.at2(t, i) * e;
            }
            let col_i: Vec<f32> = (i..m).map(|t| hinv.at2(t, i)).collect();
            for t in (i + 1)..m {
                let c_t = col_i[t - i] / dii;
                let hrow = hinv.row_mut(t);
                for s in (i + 1)..m {
                    hrow[s] -= c_t * col_i[s - i];
                }
            }
        }
    }
    LayerQuant { q, delta, zero }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn::rtn;
    use crate::quant::{OrderKind, Scheme};
    use crate::util::Rng;

    fn cfg(bits: u32) -> QuantConfig {
        QuantConfig {
            bits,
            scheme: Scheme::PerChannel,
            order: OrderKind::Cyclic,
            iters: 1,
            lam: 1.0,
        }
    }

    #[test]
    fn beats_rtn() {
        let mut rng = Rng::new(20);
        let (b, m, n) = (96, 24, 12);
        let x = Tensor::new(&[b, m], rng.normal_vec(b * m));
        let w = Tensor::new(&[m, n], rng.normal_vec(m * n)).scale(0.4);
        let g = GramSet::from_features(&x);
        for bits in [2u32, 3, 4] {
            let c = cfg(bits);
            let e_obq = g.recon_error(&w, &obq(&g, &w, &c).dequant());
            let e_rtn = g.recon_error(&w, &rtn(&w, &c).dequant());
            assert!(e_obq < e_rtn, "bits={bits}: obq {e_obq} vs rtn {e_rtn}");
        }
    }

    #[test]
    fn codes_feasible() {
        let mut rng = Rng::new(21);
        let x = Tensor::new(&[64, 16], rng.normal_vec(64 * 16));
        let w = Tensor::new(&[16, 8], rng.normal_vec(128));
        let g = GramSet::from_features(&x);
        let lq = obq(&g, &w, &cfg(3));
        assert!(lq.codes_feasible(3));
    }

    #[test]
    fn grouped_works() {
        let mut rng = Rng::new(22);
        let (rows, c, kk) = (40, 4, 9);
        let x3 = Tensor::new(&[rows, c, kk], rng.normal_vec(rows * c * kk));
        let g = GramSet::from_grouped_features(&x3);
        let w = Tensor::new(&[kk, c], rng.normal_vec(kk * c)).scale(0.3);
        let lq = obq(&g, &w, &cfg(4));
        assert!(lq.codes_feasible(4));
        let e_obq = g.recon_error(&w, &lq.dequant());
        let e_rtn = g.recon_error(&w, &rtn(&w, &cfg(4)).dequant());
        assert!(e_obq <= e_rtn + 1e-9);
    }

    #[test]
    fn singular_gram_falls_back() {
        // all-zero features: H is singular even after relative damping,
        // handled by the damping floor; error must stay finite
        let x = Tensor::zeros(&[8, 6]);
        let g = GramSet::from_features(&x);
        let mut rng = Rng::new(23);
        let w = Tensor::new(&[6, 3], rng.normal_vec(18));
        let lq = obq(&g, &w, &cfg(4));
        assert!(lq.q.data().iter().all(|v| v.is_finite()));
        assert!(lq.codes_feasible(4));
    }
}
