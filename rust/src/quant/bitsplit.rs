//! Bit-split & stitching baseline (Wang et al., ICML 2020 / TPAMI 2022),
//! adapted to the Gram-domain layer objective.
//!
//! The published method decomposes each b-bit code into bit planes,
//! optimizes one plane at a time against the layer reconstruction error
//! (a binary problem per coordinate given the other planes), then
//! "stitches" the planes back into integer codes. We keep exactly that
//! structure — offset-binary planes q = z + Σ_p 2^p u_p, u_p ∈ {0,1},
//! optimized MSB→LSB with closed-form binary coordinate updates — and
//! reuse the residual bookkeeping of the COMQ engine (P = G(W − W_q)).
//! The scale is fixed at init (the published method derives it from the
//! weight range too); the gap to COMQ in the tables therefore isolates
//! the value of full-range coordinate moves + the learned δ.

use crate::tensor::Tensor;
use crate::util::pool::parallel_ranges;

use super::comq::EPS_DIAG;
use super::gram::GramSet;
use super::grid::{init_grid, qround, LayerQuant, QuantConfig};

pub fn bitsplit(gram: &GramSet, w: &Tensor, cfg: &QuantConfig) -> LayerQuant {
    let (m, n) = (w.rows(), w.cols());
    let (delta, zero) = init_grid(w, cfg);
    let levels = cfg.levels();
    // init at RTN codes (the stitching start point)
    let mut q = Tensor::zeros(&[m, n]);
    for i in 0..m {
        let wrow = w.row(i);
        let qrow = q.row_mut(i);
        for j in 0..n {
            qrow[j] = qround(wrow[j] / delta[j], zero[j], levels);
        }
    }
    let q_ptr = QPtr(q.data_mut().as_mut_ptr());
    parallel_ranges(n, 4, |_, cols| {
        let mut p = vec![0.0f32; m];
        let mut wcol = vec![0.0f32; m];
        let mut qcol = vec![0.0f32; m];
        for j in cols {
            let g = gram.for_col(j);
            let dj = delta[j];
            let zj = zero[j];
            let qd = unsafe { std::slice::from_raw_parts_mut(q_ptr.ptr(), m * n) };
            for i in 0..m {
                wcol[i] = w.at2(i, j);
                qcol[i] = qd[i * n + j];
            }
            // residual statistics p = G (w − δ q)
            for i in 0..m {
                let grow = g.row(i);
                let mut s = 0.0f32;
                for t in 0..m {
                    s += grow[t] * (wcol[t] - dj * qcol[t]);
                }
                p[i] = s;
            }
            // plane-wise passes, MSB -> LSB, repeated `iters` times
            for _pass in 0..cfg.iters {
                for plane in (0..cfg.bits).rev() {
                    let step = (1u64 << plane) as f32;
                    for i in 0..m {
                        let gii = g.at2(i, i);
                        if gii <= EPS_DIAG {
                            continue;
                        }
                        // binary choice: bit of `plane` in (q - z) set or
                        // cleared; candidates stay within the code range
                        let u = qcol[i] - zj;
                        let bit_set = ((u as u64) >> plane) & 1 == 1;
                        let cand = if bit_set { qcol[i] - step } else { qcol[i] + step };
                        if cand < zj || cand > zj + levels {
                            continue;
                        }
                        // continuous optimum along this coordinate
                        let r_old = wcol[i] - dj * qcol[i];
                        let opt = (p[i] - gii * r_old + gii * wcol[i]) / gii / dj;
                        // pick the nearer of {current, candidate} to opt
                        let q_new = if (opt - cand).abs() < (opt - qcol[i]).abs() {
                            cand
                        } else {
                            qcol[i]
                        };
                        if q_new != qcol[i] {
                            let dr = (wcol[i] - dj * q_new) - r_old;
                            let grow = g.row(i);
                            for (pt, gt) in p.iter_mut().zip(grow) {
                                *pt += gt * dr;
                            }
                            qcol[i] = q_new;
                        }
                    }
                }
            }
            for i in 0..m {
                qd[i * n + j] = qcol[i];
            }
        }
    });
    LayerQuant { q, delta, zero }
}

struct QPtr(*mut f32);
unsafe impl Send for QPtr {}
unsafe impl Sync for QPtr {}
impl QPtr {
    #[inline]
    fn ptr(&self) -> *mut f32 {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn::rtn;
    use crate::quant::{comq_gram, OrderKind, Scheme};
    use crate::util::Rng;

    fn cfg(bits: u32) -> QuantConfig {
        QuantConfig {
            bits,
            scheme: Scheme::PerChannel,
            order: OrderKind::Cyclic,
            iters: 3,
            lam: 1.0,
        }
    }

    fn setup(seed: u64) -> (Tensor, GramSet) {
        let mut rng = Rng::new(seed);
        let (b, m, n) = (96, 24, 12);
        let x = Tensor::new(&[b, m], rng.normal_vec(b * m));
        let w = Tensor::new(&[m, n], rng.normal_vec(m * n)).scale(0.4);
        (w, GramSet::from_features(&x))
    }

    #[test]
    fn beats_rtn() {
        for seed in [90u64, 91] {
            let (w, g) = setup(seed);
            for bits in [3u32, 4] {
                let c = cfg(bits);
                let e_bs = g.recon_error(&w, &bitsplit(&g, &w, &c).dequant());
                let e_rtn = g.recon_error(&w, &rtn(&w, &c).dequant());
                assert!(e_bs < e_rtn, "seed={seed} bits={bits}: {e_bs} vs rtn {e_rtn}");
            }
        }
    }

    #[test]
    fn codes_feasible_all_bits() {
        let (w, g) = setup(92);
        for bits in [2u32, 3, 4, 8] {
            let lq = bitsplit(&g, &w, &cfg(bits));
            assert!(lq.codes_feasible(bits), "bits={bits}");
        }
    }

    #[test]
    fn comq_no_worse_on_average() {
        // COMQ's moves are a superset (any integer step + learned δ)
        let mut tot_b = 0.0;
        let mut tot_c = 0.0;
        for seed in 95..100u64 {
            let (w, g) = setup(seed);
            let c = cfg(3);
            tot_b += g.recon_error(&w, &bitsplit(&g, &w, &c).dequant());
            tot_c += g.recon_error(&w, &comq_gram(&g, &w, &c).dequant());
        }
        assert!(tot_c <= tot_b * 1.02, "comq {tot_c} vs bitsplit {tot_b}");
    }

    #[test]
    fn handles_dead_features() {
        let (w, _) = setup(97);
        let g = GramSet::Shared(Tensor::zeros(&[24, 24]));
        let lq = bitsplit(&g, &w, &cfg(4));
        assert!(lq.q.data().iter().all(|v| v.is_finite()));
        assert!(lq.codes_feasible(4));
    }
}
