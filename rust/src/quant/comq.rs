//! COMQ: coordinate-wise minimization of the layer-wise reconstruction
//! error (the paper's Alg. 1 / Alg. 2).
//!
//! Three engines, mathematically identical (tests assert agreement —
//! gram vs workspace is asserted *bit*-identical):
//!
//! * `comq_residual` (this file) — the literal Eq. 6/9 transcription
//!   carrying U = X(W − W_q) ∈ R^{b×n}; needs raw features X; O(K·m·b)
//!   per column and a batch dimension in the hot loop. Kept as the
//!   readable reference + the residual-vs-Gram perf ablation; never the
//!   production path.
//! * `comq_gram` (this file) — the Gram-domain engine carrying
//!   P = G(W − W_q) column-wise with G = XᵀX precomputed; O(K·m²) per
//!   column, no batch dimension. Row-major layout: every column visit
//!   gathers stride-`n` slices of W/Q into scratch and scatters Q back.
//!   Kept as the layout-agnostic second opinion the workspace engine is
//!   verified against.
//! * `comq_workspace` (quant/workspace.rs) — the production engine.
//!   Same math and *bit-identical codes* as `comq_gram`, but W/Q/P are
//!   packed column-major once per layer (one transpose in, one out), the
//!   batched panels P = G·R and G·Q run through the packed register-
//!   tiled matmul, greedy orders are computed once per layer instead of
//!   once per column per sweep, and all scratch is reused. Strictly
//!   faster; use it unless you are cross-checking engines.
//!
//! Columns are independent given the scale, so all engines process
//! columns in parallel (via the persistent pool in util/pool.rs);
//! per-layer mode synchronizes only at the δ-update (Eq. 7), per-channel
//! mode never does (Eq. 10 is per-column).

use crate::tensor::{axpy, Tensor};
use crate::util::pool::{parallel_ranges, SendPtr};

use super::gram::GramSet;
use super::grid::{init_grid, qround, LayerQuant, QuantConfig, Scheme};
use super::order::{order_for_column, order_for_column_into, shared_order, OrderKind};

/// Dead-feature guard: ‖x_i‖² below this falls back to plain rounding.
pub const EPS_DIAG: f32 = 1e-12;

// ---------------------------------------------------------------------------
// Gram-domain engine (the production path)
// ---------------------------------------------------------------------------

/// Quantize one layer with COMQ using Gram statistics.
pub fn comq_gram(gram: &GramSet, w: &Tensor, cfg: &QuantConfig) -> LayerQuant {
    let (m, n) = (w.rows(), w.cols());
    assert_eq!(gram.m(), m, "Gram dimension {} vs weight rows {m}", gram.m());
    let (mut delta, zero) = init_grid(w, cfg);
    // infeasible float start Q0 = W / δ (made feasible by the first sweep)
    let mut q = Tensor::zeros(&[m, n]);
    for i in 0..m {
        let wrow = w.row(i);
        let qrow = q.row_mut(i);
        for j in 0..n {
            qrow[j] = wrow[j] / delta[j];
        }
    }

    let levels = cfg.levels();
    for _k in 0..cfg.iters {
        // -- Q-update: sweep every column (parallel; columns independent) --
        let new_deltas = sweep_columns_gram(gram, w, &mut q, &delta, &zero, levels, cfg);
        // -- δ-update --
        match cfg.scheme {
            Scheme::PerChannel => {
                for (d, nd) in delta.iter_mut().zip(&new_deltas) {
                    if nd.1 > 0.0 {
                        *d = nd.0 / nd.1;
                    }
                }
            }
            Scheme::PerLayer => {
                let num: f64 = new_deltas.iter().map(|p| p.0 as f64).sum();
                let den: f64 = new_deltas.iter().map(|p| p.1 as f64).sum();
                if den > 0.0 {
                    let d = (num / den) as f32;
                    delta.iter_mut().for_each(|x| *x = d);
                }
            }
        }
    }
    LayerQuant { q, delta, zero }
}

/// One full sweep over all columns. Returns per-column (num, den) for the
/// δ-update: num_j = q_jᵀ G w_j, den_j = q_jᵀ G q_j.
fn sweep_columns_gram(
    gram: &GramSet,
    w: &Tensor,
    q: &mut Tensor,
    delta: &[f32],
    zero: &[f32],
    levels: f32,
    cfg: &QuantConfig,
) -> Vec<(f32, f32)> {
    let (m, n) = (w.rows(), w.cols());
    // Shared-Gram fast path: compute P = G (W − Q diag δ) for ALL columns
    // as one blocked matmul instead of n separate gemvs (perf iteration
    // #6 in EXPERIMENTS.md §Perf — the gemvs were ~2/3 of sweep FLOPs
    // and the blocked kernel has far better cache behaviour).
    let p_all: Option<Tensor> = match gram {
        GramSet::Shared(g) => {
            let mut r = Tensor::zeros(&[m, n]);
            for i in 0..m {
                let wrow = w.row(i);
                let qrow = q.row(i);
                let rrow = r.row_mut(i);
                for j in 0..n {
                    rrow[j] = wrow[j] - delta[j] * qrow[j];
                }
            }
            Some(crate::tensor::matmul(g, &r))
        }
        GramSet::Grouped(_) => None,
    };
    // Column-invariant work hoisted out of the per-column loop: the
    // shared diag(G), and the update order when it does not depend on j
    // (Cyclic always; GreedyShared whenever the Gram is shared — grouped
    // layers have per-column diags, so their "shared" order still varies).
    let diag_shared: Option<Vec<f32>> = match gram {
        GramSet::Shared(g) => Some((0..m).map(|i| g.at2(i, i)).collect()),
        GramSet::Grouped(_) => None,
    };
    let hoisted_order: Option<Vec<u32>> = match cfg.order {
        OrderKind::Cyclic => Some((0..m as u32).collect()),
        OrderKind::GreedyShared => diag_shared.as_ref().map(|d| shared_order(d, w)),
        OrderKind::GreedyPerColumn => None,
    };
    let mut out = vec![(0.0f32, 0.0f32); n];
    let q_ptr = SendPtr::new(q.data_mut().as_mut_ptr());
    let out_ptr = SendPtr::new(out.as_mut_ptr());
    // Columns are fully independent within a sweep; partition them.
    parallel_ranges(n, 4, |_, cols| {
        // scratch reused across this thread's columns
        let mut wcol = vec![0.0f32; m];
        let mut qcol = vec![0.0f32; m];
        let mut p = vec![0.0f32; m];
        let mut diag = vec![0.0f32; m];
        let mut gq = vec![0.0f32; m];
        let mut r_scratch = vec![0.0f32; m];
        let mut scores = Vec::new();
        let mut ord_scratch: Vec<u32> = Vec::new();
        for j in cols {
            let g = gram.for_col(j);
            let qd = unsafe { std::slice::from_raw_parts_mut(q_ptr.ptr(), m * n) };
            for i in 0..m {
                wcol[i] = w.at2(i, j);
                qcol[i] = qd[i * n + j];
            }
            let diag: &[f32] = match &diag_shared {
                Some(d) => d,
                None => {
                    for i in 0..m {
                        diag[i] = g.at2(i, i);
                    }
                    &diag
                }
            };
            let dj = delta[j];
            let zj = zero[j];
            let order: &[u32] = match &hoisted_order {
                Some(o) => o,
                None => {
                    order_for_column_into(cfg.order, diag, w, j, &mut scores, &mut ord_scratch);
                    &ord_scratch
                }
            };
            // p = G (w − δ q): column slice of the batched P, or per-
            // column gemv for grouped layers
            match &p_all {
                Some(pa) => {
                    for i in 0..m {
                        p[i] = pa.at2(i, j);
                    }
                }
                None => gemv_diff(g, &wcol, &qcol, dj, &mut p, &mut r_scratch),
            }
            update_column(g, diag, &wcol, &mut qcol, &mut p, order, dj, zj, levels);
            // write back
            for i in 0..m {
                qd[i * n + j] = qcol[i];
            }
            // δ-update statistics: grouped layers compute their own gemv
            // here; the shared case batches G·Q below (one matmul).
            if p_all.is_none() {
                gemv(g, &qcol, &mut gq);
                let mut num = 0.0f64;
                let mut den = 0.0f64;
                for i in 0..m {
                    num += gq[i] as f64 * wcol[i] as f64;
                    den += gq[i] as f64 * qcol[i] as f64;
                }
                let od = unsafe { std::slice::from_raw_parts_mut(out_ptr.ptr(), n) };
                od[j] = (num as f32, den as f32);
            }
        }
    });
    if let GramSet::Shared(g) = gram {
        // batched δ statistics: GQ = G·Q, then per-column dots
        let gq = crate::tensor::matmul(g, q);
        let mut num = vec![0.0f64; n];
        let mut den = vec![0.0f64; n];
        for i in 0..m {
            let gqr = gq.row(i);
            let wr = w.row(i);
            let qr = q.row(i);
            for j in 0..n {
                num[j] += gqr[j] as f64 * wr[j] as f64;
                den[j] += gqr[j] as f64 * qr[j] as f64;
            }
        }
        for j in 0..n {
            out[j] = (num[j] as f32, den[j] as f32);
        }
    }
    out
}

/// The coordinate-descent inner loop for one column (Eq. 6 in Gram
/// form): visit rows in `order`, re-round each against the current
/// residual statistics p = G(w − δq), and fold the residual change back
/// into p with a rank-1 axpy. Shared verbatim by the gram and workspace
/// engines — their bit-identity rests on this being the same code.
/// `diag[i]` must equal g[i][i].
#[allow(clippy::too_many_arguments)]
pub(crate) fn update_column(
    g: &Tensor,
    diag: &[f32],
    wcol: &[f32],
    qcol: &mut [f32],
    p: &mut [f32],
    order: &[u32],
    dj: f32,
    zj: f32,
    levels: f32,
) {
    for &oi in order {
        let i = oi as usize;
        let gii = diag[i];
        let r_old = wcol[i] - dj * qcol[i];
        let q_new = if gii <= EPS_DIAG {
            qround(wcol[i] / dj, zj, levels)
        } else {
            let numer = p[i] - gii * r_old + gii * wcol[i];
            qround(numer / gii / dj, zj, levels)
        };
        let r_new = wcol[i] - dj * q_new;
        let dr = r_new - r_old;
        if dr != 0.0 {
            axpy(dr, g.row(i), p); // symmetric: column i == row i
        }
        qcol[i] = q_new;
    }
}

/// p = G (w − δ q); `r` is caller-owned scratch (length ≥ m) so the hot
/// loop makes no per-call allocation.
pub(crate) fn gemv_diff(g: &Tensor, w: &[f32], q: &[f32], delta: f32, p: &mut [f32], r: &mut [f32]) {
    let m = w.len();
    for i in 0..m {
        r[i] = w[i] - delta * q[i];
    }
    gemv(g, &r[..m], p);
}

/// p = G v (G symmetric [m, m]); 8-way unrolled dot so the compiler
/// vectorizes with independent accumulator lanes (same shape as the
/// matmul axpy kernel — perf iteration #3 in EXPERIMENTS.md §Perf).
pub(crate) fn gemv(g: &Tensor, v: &[f32], p: &mut [f32]) {
    let m = v.len();
    let gd = g.data();
    for (i, pi) in p.iter_mut().enumerate() {
        *pi = dot(&gd[i * m..(i + 1) * m], v);
    }
}

/// 8-lane unrolled dot product.
#[inline]
pub(crate) fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let split = n - n % 8;
    let mut acc = [0.0f32; 8];
    for (a8, b8) in a[..split].chunks_exact(8).zip(b[..split].chunks_exact(8)) {
        for l in 0..8 {
            acc[l] += a8[l] * b8[l];
        }
    }
    let mut s = (acc[0] + acc[4]) + (acc[1] + acc[5]) + (acc[2] + acc[6]) + (acc[3] + acc[7]);
    for (x, y) in a[split..].iter().zip(&b[split..]) {
        s += x * y;
    }
    s
}

// ---------------------------------------------------------------------------
// Residual-domain engine (Eq. 6/9 verbatim; the reference path)
// ---------------------------------------------------------------------------

/// Quantize one layer with COMQ carrying raw residuals U = X(W − W_q).
/// Requires raw calibration features x [b, m]. Used for validation and
/// for the residual-vs-Gram perf ablation (micro_hotpath bench).
pub fn comq_residual(x: &Tensor, w: &Tensor, cfg: &QuantConfig) -> LayerQuant {
    let (b, m) = (x.rows(), x.cols());
    let n = w.cols();
    assert_eq!(w.rows(), m);
    let (mut delta, zero) = init_grid(w, cfg);
    let mut q = Tensor::zeros(&[m, n]);
    for i in 0..m {
        for j in 0..n {
            q.data_mut()[i * n + j] = w.at2(i, j) / delta[j];
        }
    }
    // precompute ‖x_i‖² and columns of X
    let norms: Vec<f32> = (0..m)
        .map(|i| (0..b).map(|r| x.at2(r, i) * x.at2(r, i)).sum())
        .collect();
    let xt = x.transpose2(); // [m, b]: row i = x_i

    let levels = cfg.levels();
    for _k in 0..cfg.iters {
        let mut stats = vec![(0.0f64, 0.0f64); n];
        for j in 0..n {
            let dj = delta[j];
            let zj = zero[j];
            let wcol: Vec<f32> = (0..m).map(|i| w.at2(i, j)).collect();
            let mut qcol: Vec<f32> = (0..m).map(|i| q.at2(i, j)).collect();
            // u = X (w − δ q)
            let mut u = vec![0.0f32; b];
            for i in 0..m {
                let r = wcol[i] - dj * qcol[i];
                if r == 0.0 {
                    continue;
                }
                let xi = xt.row(i);
                for (us, xs) in u.iter_mut().zip(xi) {
                    *us += xs * r;
                }
            }
            let order = order_for_column(cfg.order, &norms, w, j);
            for &oi in &order {
                let i = oi as usize;
                let xi = xt.row(i);
                let r_old = wcol[i] - dj * qcol[i];
                // u1 = u − x_i r_old;  numer = <u1 + x_i w_i, x_i>
                let mut dot = 0.0f32;
                for (us, xs) in u.iter().zip(xi) {
                    dot += (us - xs * r_old + xs * wcol[i]) * xs;
                }
                let q_new = if norms[i] <= EPS_DIAG {
                    qround(wcol[i] / dj, zj, levels)
                } else {
                    qround(dot / norms[i] / dj, zj, levels)
                };
                let r_new = wcol[i] - dj * q_new;
                let dr = r_new - r_old;
                if dr != 0.0 {
                    for (us, xs) in u.iter_mut().zip(xi) {
                        *us += xs * dr;
                    }
                }
                qcol[i] = q_new;
            }
            // δ statistics from raw X: num = <Xq, Xw>, den = ‖Xq‖²
            let mut xq = vec![0.0f32; b];
            let mut xw = vec![0.0f32; b];
            for i in 0..m {
                let xi = xt.row(i);
                for r in 0..b {
                    xq[r] += xi[r] * qcol[i];
                    xw[r] += xi[r] * wcol[i];
                }
            }
            let num: f64 = xq.iter().zip(&xw).map(|(a, c)| *a as f64 * *c as f64).sum();
            let den: f64 = xq.iter().map(|a| *a as f64 * *a as f64).sum();
            stats[j] = (num, den);
            for i in 0..m {
                q.data_mut()[i * n + j] = qcol[i];
            }
        }
        match cfg.scheme {
            Scheme::PerChannel => {
                for (j, d) in delta.iter_mut().enumerate() {
                    if stats[j].1 > 0.0 {
                        *d = (stats[j].0 / stats[j].1) as f32;
                    }
                }
            }
            Scheme::PerLayer => {
                let num: f64 = stats.iter().map(|s| s.0).sum();
                let den: f64 = stats.iter().map(|s| s.1).sum();
                if den > 0.0 {
                    let d = (num / den) as f32;
                    delta.iter_mut().for_each(|x| *x = d);
                }
            }
        }
    }
    LayerQuant { q, delta, zero }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::gram::recon_error_from_x;
    use crate::quant::rtn::rtn;
    use crate::util::Rng;

    fn setup(b: usize, m: usize, n: usize, seed: u64) -> (Tensor, Tensor, GramSet) {
        let mut rng = Rng::new(seed);
        let x = Tensor::new(&[b, m], rng.normal_vec(b * m));
        let w = Tensor::new(&[m, n], rng.normal_vec(m * n)).scale(0.5);
        let g = GramSet::from_features(&x);
        (x, w, g)
    }

    #[test]
    fn gram_matches_residual_engine() {
        let (x, w, g) = setup(64, 24, 12, 10);
        for bits in [2u32, 3, 4] {
            for scheme in [Scheme::PerChannel, Scheme::PerLayer] {
                let cfg = QuantConfig { bits, scheme, order: OrderKind::Cyclic, iters: 3, lam: 1.0 };
                let a = comq_gram(&g, &w, &cfg);
                let b2 = comq_residual(&x, &w, &cfg);
                // identical codes on well-conditioned random input
                let same = a
                    .q
                    .data()
                    .iter()
                    .zip(b2.q.data())
                    .filter(|(p, q)| p == q)
                    .count();
                let frac = same as f64 / a.q.len() as f64;
                assert!(frac > 0.98, "bits={bits} {scheme:?}: only {frac} codes agree");
                let ea = g.recon_error(&w, &a.dequant());
                let eb = g.recon_error(&w, &b2.dequant());
                assert!(
                    (ea - eb).abs() <= 0.05 * ea.max(1e-6),
                    "bits={bits} {scheme:?}: {ea} vs {eb}"
                );
            }
        }
    }

    #[test]
    fn beats_rtn() {
        let (x, w, g) = setup(128, 32, 16, 11);
        for bits in [2u32, 3, 4] {
            let cfg = QuantConfig { bits, ..Default::default() };
            let lq = comq_gram(&g, &w, &cfg);
            let r = rtn(&w, &cfg);
            let e_comq = recon_error_from_x(&x, &w, &lq.dequant());
            let e_rtn = recon_error_from_x(&x, &w, &r.dequant());
            assert!(
                e_comq < e_rtn,
                "bits={bits}: comq {e_comq} not better than rtn {e_rtn}"
            );
        }
    }

    #[test]
    fn codes_feasible_all_modes() {
        let (_, w, g) = setup(48, 16, 8, 12);
        for scheme in [Scheme::PerChannel, Scheme::PerLayer] {
            for order in [OrderKind::Cyclic, OrderKind::GreedyShared, OrderKind::GreedyPerColumn] {
                let cfg = QuantConfig { bits: 3, scheme, order, iters: 2, lam: 0.9 };
                let lq = comq_gram(&g, &w, &cfg);
                assert!(lq.codes_feasible(3), "{scheme:?} {order:?}");
            }
        }
    }

    #[test]
    fn greedy_no_worse_than_cyclic_on_average() {
        // Aggregate over seeds: greedy should win or tie in total error
        let mut tot_c = 0.0;
        let mut tot_g = 0.0;
        for seed in 0..5 {
            let (_, w, g) = setup(96, 24, 12, 100 + seed);
            let base = QuantConfig { bits: 3, iters: 3, ..Default::default() };
            let c = comq_gram(&g, &w, &QuantConfig { order: OrderKind::Cyclic, ..base });
            let gr = comq_gram(&g, &w, &QuantConfig { order: OrderKind::GreedyPerColumn, ..base });
            tot_c += g.recon_error(&w, &c.dequant());
            tot_g += g.recon_error(&w, &gr.dequant());
        }
        assert!(tot_g <= tot_c * 1.02, "greedy {tot_g} vs cyclic {tot_c}");
    }

    #[test]
    fn iterations_monotone_early() {
        // error(K=3) <= error(K=1) (paper Tab. 7: a few sweeps help)
        let (_, w, g) = setup(64, 20, 10, 42);
        let e1 = {
            let cfg = QuantConfig { bits: 4, iters: 1, ..Default::default() };
            g.recon_error(&w, &comq_gram(&g, &w, &cfg).dequant())
        };
        let e3 = {
            let cfg = QuantConfig { bits: 4, iters: 3, ..Default::default() };
            g.recon_error(&w, &comq_gram(&g, &w, &cfg).dequant())
        };
        assert!(e3 <= e1 * 1.001, "K=3 {e3} vs K=1 {e1}");
    }

    #[test]
    fn grouped_layers_quantize() {
        let mut rng = Rng::new(13);
        let (rows, c, kk) = (40, 6, 9);
        let x3 = Tensor::new(&[rows, c, kk], rng.normal_vec(rows * c * kk));
        let g = GramSet::from_grouped_features(&x3);
        let w = Tensor::new(&[kk, c], rng.normal_vec(kk * c)).scale(0.3);
        let cfg = QuantConfig { bits: 4, ..Default::default() };
        let lq = comq_gram(&g, &w, &cfg);
        assert!(lq.codes_feasible(4));
        let e = g.recon_error(&w, &lq.dequant());
        let e_rtn = g.recon_error(&w, &rtn(&w, &cfg).dequant());
        assert!(e <= e_rtn + 1e-9, "grouped comq {e} vs rtn {e_rtn}");
    }

    #[test]
    fn handles_dead_features() {
        // zero out a feature column of X: its Gram row/col is zero
        let mut rng = Rng::new(14);
        let (b, m, n) = (32, 10, 4);
        let mut xd = rng.normal_vec(b * m);
        for r in 0..b {
            xd[r * m + 3] = 0.0;
        }
        let x = Tensor::new(&[b, m], xd);
        let g = GramSet::from_features(&x);
        let w = Tensor::new(&[m, n], rng.normal_vec(m * n));
        let cfg = QuantConfig::default();
        let lq = comq_gram(&g, &w, &cfg);
        assert!(lq.codes_feasible(4));
        assert!(lq.q.data().iter().all(|v| v.is_finite()));
    }
}
