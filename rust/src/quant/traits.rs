//! The object-safe quantizer interface + name registry used by the
//! coordinator's config system and the benches.

use crate::tensor::Tensor;

use super::adaround::adaround_lite;
use super::bitsplit::bitsplit;
use super::comq::comq_gram;
use super::gpfq::gpfq;
use super::workspace::comq_workspace;
use super::gram::GramSet;
use super::grid::{LayerQuant, QuantConfig};
use super::obq::obq;
use super::order::OrderKind;
use super::rtn::rtn;

/// A weight quantization method operating on (Gram, W).
pub trait Quantizer: Send + Sync {
    fn name(&self) -> &'static str;
    fn quantize(&self, gram: &GramSet, w: &Tensor, cfg: &QuantConfig) -> LayerQuant;
    /// Whether the method reads the calibration Gram at all.
    fn uses_calibration(&self) -> bool {
        true
    }
}

pub struct ComqQuantizer;
pub struct ComqGramQuantizer;
pub struct ComqCyclicQuantizer;
pub struct RtnQuantizer;
pub struct GpfqQuantizer;
pub struct ObqQuantizer;
pub struct AdaRoundLiteQuantizer;
pub struct BitSplitQuantizer;

impl Quantizer for ComqQuantizer {
    fn name(&self) -> &'static str {
        "comq"
    }
    fn quantize(&self, gram: &GramSet, w: &Tensor, cfg: &QuantConfig) -> LayerQuant {
        // production path: column-major workspace engine (bit-identical
        // to comq_gram)
        comq_workspace(gram, w, cfg)
    }
}

impl Quantizer for ComqGramQuantizer {
    fn name(&self) -> &'static str {
        "comq-gram"
    }
    fn quantize(&self, gram: &GramSet, w: &Tensor, cfg: &QuantConfig) -> LayerQuant {
        // row-major Gram-domain engine, kept as the second opinion the
        // workspace engine is verified against
        comq_gram(gram, w, cfg)
    }
}

impl Quantizer for ComqCyclicQuantizer {
    fn name(&self) -> &'static str {
        "comq-cyclic"
    }
    fn quantize(&self, gram: &GramSet, w: &Tensor, cfg: &QuantConfig) -> LayerQuant {
        let cfg = QuantConfig { order: OrderKind::Cyclic, ..*cfg };
        comq_workspace(gram, w, &cfg)
    }
}

impl Quantizer for RtnQuantizer {
    fn name(&self) -> &'static str {
        "rtn"
    }
    fn quantize(&self, _gram: &GramSet, w: &Tensor, cfg: &QuantConfig) -> LayerQuant {
        rtn(w, cfg)
    }
    fn uses_calibration(&self) -> bool {
        false
    }
}

impl Quantizer for GpfqQuantizer {
    fn name(&self) -> &'static str {
        "gpfq"
    }
    fn quantize(&self, gram: &GramSet, w: &Tensor, cfg: &QuantConfig) -> LayerQuant {
        gpfq(gram, w, cfg)
    }
}

impl Quantizer for ObqQuantizer {
    fn name(&self) -> &'static str {
        "obq"
    }
    fn quantize(&self, gram: &GramSet, w: &Tensor, cfg: &QuantConfig) -> LayerQuant {
        obq(gram, w, cfg)
    }
}

impl Quantizer for AdaRoundLiteQuantizer {
    fn name(&self) -> &'static str {
        "adaround-lite"
    }
    fn quantize(&self, gram: &GramSet, w: &Tensor, cfg: &QuantConfig) -> LayerQuant {
        adaround_lite(gram, w, cfg)
    }
}

impl Quantizer for BitSplitQuantizer {
    fn name(&self) -> &'static str {
        "bitsplit"
    }
    fn quantize(&self, gram: &GramSet, w: &Tensor, cfg: &QuantConfig) -> LayerQuant {
        bitsplit(gram, w, cfg)
    }
}

/// Every registered quantizer name (CLI/docs).
pub const QUANTIZER_NAMES: &[&str] =
    &["comq", "comq-gram", "comq-cyclic", "rtn", "gpfq", "obq", "adaround-lite", "bitsplit"];

/// Factory.
pub fn make_quantizer(name: &str) -> Option<Box<dyn Quantizer>> {
    match name {
        "comq" => Some(Box::new(ComqQuantizer)),
        "comq-gram" => Some(Box::new(ComqGramQuantizer)),
        "comq-cyclic" => Some(Box::new(ComqCyclicQuantizer)),
        "rtn" => Some(Box::new(RtnQuantizer)),
        "gpfq" => Some(Box::new(GpfqQuantizer)),
        "obq" => Some(Box::new(ObqQuantizer)),
        "adaround-lite" => Some(Box::new(AdaRoundLiteQuantizer)),
        "bitsplit" => Some(Box::new(BitSplitQuantizer)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn registry_complete() {
        for name in QUANTIZER_NAMES {
            let q = make_quantizer(name).unwrap_or_else(|| panic!("{name} missing"));
            assert_eq!(q.name(), *name);
        }
        assert!(make_quantizer("nope").is_none());
    }

    #[test]
    fn all_quantizers_produce_feasible_codes() {
        let mut rng = Rng::new(33);
        let x = Tensor::new(&[48, 12], rng.normal_vec(48 * 12));
        let w = Tensor::new(&[12, 6], rng.normal_vec(72));
        let g = GramSet::from_features(&x);
        let cfg = QuantConfig::default();
        for name in QUANTIZER_NAMES {
            let lq = make_quantizer(name).unwrap().quantize(&g, &w, &cfg);
            assert!(lq.codes_feasible(cfg.bits), "{name}");
            assert_eq!(lq.q.shape(), w.shape(), "{name}");
        }
    }
}
