//! Dense symmetric positive-definite linear algebra for the OBQ baseline:
//! Cholesky factorization and SPD inversion, with diagonal damping.

use anyhow::{bail, Result};

use crate::tensor::Tensor;

/// Lower Cholesky factor L of an SPD matrix A = L Lᵀ (in-place layout).
pub fn cholesky(a: &Tensor) -> Result<Tensor> {
    let m = a.rows();
    assert_eq!(a.cols(), m);
    let mut l = Tensor::zeros(&[m, m]);
    for i in 0..m {
        for j in 0..=i {
            let mut s = a.at2(i, j) as f64;
            for k in 0..j {
                s -= l.at2(i, k) as f64 * l.at2(j, k) as f64;
            }
            if i == j {
                if s <= 0.0 {
                    bail!("matrix not positive definite at pivot {i} (s={s})");
                }
                l.data_mut()[i * m + j] = (s.sqrt()) as f32;
            } else {
                l.data_mut()[i * m + j] = (s / l.at2(j, j) as f64) as f32;
            }
        }
    }
    Ok(l)
}

/// Inverse of an SPD matrix via Cholesky: A⁻¹ = L⁻ᵀ L⁻¹.
pub fn invert_spd(a: &Tensor) -> Result<Tensor> {
    let m = a.rows();
    let l = cholesky(a)?;
    // Invert lower-triangular L
    let mut linv = Tensor::zeros(&[m, m]);
    for i in 0..m {
        linv.data_mut()[i * m + i] = 1.0 / l.at2(i, i);
        for j in 0..i {
            let mut s = 0.0f64;
            for k in j..i {
                s += l.at2(i, k) as f64 * linv.at2(k, j) as f64;
            }
            linv.data_mut()[i * m + j] = (-s / l.at2(i, i) as f64) as f32;
        }
    }
    // A⁻¹ = Linvᵀ Linv
    let mut out = Tensor::zeros(&[m, m]);
    for i in 0..m {
        for j in 0..m {
            let mut s = 0.0f64;
            for k in i.max(j)..m {
                s += linv.at2(k, i) as f64 * linv.at2(k, j) as f64;
            }
            out.data_mut()[i * m + j] = s as f32;
        }
    }
    Ok(out)
}

/// A + λ·mean(diag)·I — the damping OBQ/GPTQ uses to keep H invertible.
pub fn damped(a: &Tensor, lam: f64) -> Tensor {
    let m = a.rows();
    let mean_diag: f64 =
        (0..m).map(|i| a.at2(i, i) as f64).sum::<f64>() / m as f64;
    let add = (lam * mean_diag.max(1e-12)) as f32;
    let mut out = a.clone();
    for i in 0..m {
        out.data_mut()[i * m + i] += add;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{matmul, matmul_at_a};
    use crate::util::Rng;

    fn random_spd(m: usize, seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        let a = Tensor::new(&[m + 4, m], rng.normal_vec((m + 4) * m));
        damped(&matmul_at_a(&a), 0.01)
    }

    #[test]
    fn cholesky_reconstructs() {
        for m in [1, 3, 8, 20] {
            let a = random_spd(m, m as u64);
            let l = cholesky(&a).unwrap();
            let rec = matmul(&l, &l.transpose2());
            assert!(rec.max_abs_diff(&a) < 1e-2, "m={m}");
        }
    }

    #[test]
    fn inverse_is_inverse() {
        for m in [2, 5, 16] {
            let a = random_spd(m, 100 + m as u64);
            let inv = invert_spd(&a).unwrap();
            let prod = matmul(&a, &inv);
            for i in 0..m {
                for j in 0..m {
                    let expect = if i == j { 1.0 } else { 0.0 };
                    assert!(
                        (prod.at2(i, j) - expect).abs() < 1e-2,
                        "m={m} ({i},{j}) = {}",
                        prod.at2(i, j)
                    );
                }
            }
        }
    }

    #[test]
    fn rejects_indefinite() {
        let a = Tensor::new(&[2, 2], vec![1.0, 2.0, 2.0, 1.0]); // eigenvalues 3, -1
        assert!(cholesky(&a).is_err());
    }

    #[test]
    fn damping_fixes_singular() {
        let a = Tensor::zeros(&[3, 3]); // singular
        let d = damped(&a, 0.01);
        // mean diag is 0 -> floor kicks in; still PD after damping floor
        assert!(cholesky(&d).is_ok());
    }
}
