//! The quantization core: COMQ (the paper's contribution) plus every
//! baseline the paper compares against, all backpropagation-free and all
//! consuming the same calibration interface (`GramSet`).
//!
//! Layout:
//! * `grid`     — asymmetric uniform b-bit grids, bit-code packing
//! * `gram`     — calibration sufficient statistics (G = XᵀX)
//! * `order`    — cyclic vs greedy coordinate orders (Sec. 3.3)
//! * `comq`     — Alg. 1 / Alg. 2, residual- and Gram-domain engines
//! * `workspace`— column-major sweep workspace (the production engine;
//!                bit-identical to `comq::comq_gram`, strictly faster)
//! * `rtn`      — round-to-nearest baseline
//! * `gpfq`     — greedy path-following quantization (Zhang et al.)
//! * `obq`      — OBQ/GPTQ-style Hessian-based baseline
//! * `adaround` — gradient-free adaptive-rounding baseline
//! * `bitsplit` — plane-wise bit-split & stitching baseline (Wang et al.)
//! * `actq`     — activation quantization (scales from calib min/max)
//! * `linalg`   — Cholesky factorization/inversion for `obq`
//! * `traits`   — the `Quantizer` object interface + registry names

pub mod actq;
pub mod adaround;
pub mod bitsplit;
pub mod comq;
pub mod gpfq;
pub mod gram;
pub mod grid;
pub mod linalg;
pub mod obq;
pub mod order;
pub mod rtn;
pub mod traits;
pub mod workspace;

pub use comq::{comq_gram, comq_residual};
pub use workspace::comq_workspace;
pub use gram::GramSet;
pub use grid::{LayerQuant, QuantConfig, Scheme};
pub use order::OrderKind;
pub use traits::{make_quantizer, Quantizer, QUANTIZER_NAMES};
