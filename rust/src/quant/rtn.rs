//! RTN: round-to-nearest, the calibration-free baseline.
//!
//! W_q = δ · clip(round(W/δ), z, z + 2^b − 1) with the same grid init as
//! COMQ (so differences in the tables isolate the *optimization*, not the
//! grid). This is what "min-max uniform quantization" means in the
//! paper's comparison tables.

use crate::tensor::Tensor;

use super::grid::{init_grid, qround, LayerQuant, QuantConfig};

pub fn rtn(w: &Tensor, cfg: &QuantConfig) -> LayerQuant {
    let (m, n) = (w.rows(), w.cols());
    let (delta, zero) = init_grid(w, cfg);
    let levels = cfg.levels();
    let mut q = Tensor::zeros(&[m, n]);
    for i in 0..m {
        let wrow = w.row(i);
        let qrow = q.row_mut(i);
        for j in 0..n {
            qrow[j] = qround(wrow[j] / delta[j], zero[j], levels);
        }
    }
    LayerQuant { q, delta, zero }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::grid::Scheme;
    use crate::quant::OrderKind;
    use crate::util::Rng;

    fn cfg(bits: u32, scheme: Scheme) -> QuantConfig {
        QuantConfig { bits, scheme, order: OrderKind::Cyclic, iters: 1, lam: 1.0 }
    }

    #[test]
    fn codes_feasible() {
        let mut rng = Rng::new(1);
        let w = Tensor::new(&[16, 8], rng.normal_vec(128));
        for bits in [2u32, 3, 4, 8] {
            for scheme in [Scheme::PerChannel, Scheme::PerLayer] {
                let lq = rtn(&w, &cfg(bits, scheme));
                assert!(lq.codes_feasible(bits), "bits={bits} {scheme:?}");
            }
        }
    }

    #[test]
    fn high_bits_near_lossless() {
        let mut rng = Rng::new(2);
        let w = Tensor::new(&[32, 8], rng.normal_vec(256));
        let lq = rtn(&w, &cfg(8, Scheme::PerChannel));
        let err = w.max_abs_diff(&lq.dequant());
        // max error <= delta/2 <= range/(2*255)
        assert!(err < 0.02, "8-bit rtn max err {err}");
    }

    #[test]
    fn per_channel_beats_per_layer_on_skewed_columns() {
        // one tiny column + one huge column: shared scale murders the tiny one
        let mut w = Tensor::zeros(&[16, 2]);
        let mut rng = Rng::new(3);
        for i in 0..16 {
            w.data_mut()[i * 2] = rng.normal() * 0.01;
            w.data_mut()[i * 2 + 1] = rng.normal() * 10.0;
        }
        let pc = rtn(&w, &cfg(4, Scheme::PerChannel)).dequant();
        let pl = rtn(&w, &cfg(4, Scheme::PerLayer)).dequant();
        let err_col0 = |wq: &Tensor| -> f32 {
            (0..16).map(|i| (wq.at2(i, 0) - w.at2(i, 0)).abs()).sum()
        };
        assert!(err_col0(&pc) < err_col0(&pl));
    }

    #[test]
    fn exact_grid_points_roundtrip() {
        // weights already on the grid stay put
        let cfgc = cfg(4, Scheme::PerChannel);
        let w = Tensor::new(&[4, 1], vec![0.0, 0.5, 1.0, 1.5]);
        let lq = rtn(&w, &cfgc);
        let wq = lq.dequant();
        assert!(w.max_abs_diff(&wq) < 1e-6);
    }
}
