//! Calibration sufficient statistics.
//!
//! The layer-wise objective ‖X W_q − X W‖² depends on the calibration
//! features X only through the Gram matrix G = XᵀX (and the init only on
//! W), so the calibration manager stores G instead of raw activations —
//! O(m²) instead of O(b·m) memory, and the COMQ hot loop drops the batch
//! dimension entirely (see DESIGN.md §4).
//!
//! Depthwise (grouped) layers get one small Gram per group: output
//! channel j only sees its own k·k patch block.

use anyhow::{bail, Result};

use crate::tensor::{matmul, matmul_at_a, Tensor};

/// Gram statistics for one layer.
#[derive(Debug, Clone)]
pub enum GramSet {
    /// All columns share G = XᵀX [m, m].
    Shared(Tensor),
    /// Column j uses its own G_j (depthwise conv): `groups[j]` is [kk, kk].
    Grouped(Vec<Tensor>),
}

impl GramSet {
    /// Build from raw features X [b, m].
    pub fn from_features(x: &Tensor) -> GramSet {
        GramSet::Shared(matmul_at_a(x))
    }

    /// Build from grouped features X3 [rows, groups, kk].
    pub fn from_grouped_features(x3: &Tensor) -> GramSet {
        assert_eq!(x3.ndim(), 3);
        let (rows, c, kk) = (x3.shape()[0], x3.shape()[1], x3.shape()[2]);
        let mut groups = Vec::with_capacity(c);
        for ch in 0..c {
            // gather [rows, kk] slice for channel ch
            let mut xc = Tensor::zeros(&[rows, kk]);
            for r in 0..rows {
                let src = &x3.data()[(r * c + ch) * kk..(r * c + ch + 1) * kk];
                xc.data_mut()[r * kk..(r + 1) * kk].copy_from_slice(src);
            }
            groups.push(matmul_at_a(&xc));
        }
        GramSet::Grouped(groups)
    }

    /// Row dimension m of the weight this Gram calibrates.
    pub fn m(&self) -> usize {
        match self {
            GramSet::Shared(g) => g.rows(),
            GramSet::Grouped(gs) => gs[0].rows(),
        }
    }

    pub fn is_grouped(&self) -> bool {
        matches!(self, GramSet::Grouped(_))
    }

    /// The Gram used by column j.
    pub fn for_col(&self, j: usize) -> &Tensor {
        match self {
            GramSet::Shared(g) => g,
            GramSet::Grouped(gs) => &gs[j],
        }
    }

    /// diag of the shared Gram (column norms² of X).
    pub fn shared(&self) -> Result<&Tensor> {
        match self {
            GramSet::Shared(g) => Ok(g),
            GramSet::Grouped(_) => bail!("layer is grouped; no shared Gram"),
        }
    }

    /// Accumulate another batch's statistics (same shape).
    pub fn accumulate(&mut self, other: &GramSet) {
        match (self, other) {
            (GramSet::Shared(a), GramSet::Shared(b)) => a.add_assign(b),
            (GramSet::Grouped(a), GramSet::Grouped(b)) => {
                assert_eq!(a.len(), b.len());
                for (x, y) in a.iter_mut().zip(b) {
                    x.add_assign(y);
                }
            }
            _ => panic!("mismatched GramSet variants"),
        }
    }

    /// ‖X W_q − X W‖² = Σ_j d_jᵀ G_j d_j  with d = w_q − w (f64 accumulate).
    pub fn recon_error(&self, w: &Tensor, wq: &Tensor) -> f64 {
        assert_eq!(w.shape(), wq.shape());
        let (m, n) = (w.rows(), w.cols());
        let mut total = 0.0f64;
        for j in 0..n {
            let g = self.for_col(j);
            let d: Vec<f32> = (0..m).map(|i| wq.at2(i, j) - w.at2(i, j)).collect();
            // dᵀ G d
            let gd = g.rows();
            debug_assert_eq!(gd, m);
            for i in 0..m {
                if d[i] == 0.0 {
                    continue;
                }
                let grow = g.row(i);
                let mut s = 0.0f64;
                for t in 0..m {
                    s += grow[t] as f64 * d[t] as f64;
                }
                total += d[i] as f64 * s;
            }
        }
        total.max(0.0)
    }

    /// Per-layer error decomposed per column (for Fig. 3 reporting).
    pub fn recon_error_per_col(&self, w: &Tensor, wq: &Tensor) -> Vec<f64> {
        let (m, n) = (w.rows(), w.cols());
        (0..n)
            .map(|j| {
                let g = self.for_col(j);
                let d: Vec<f64> =
                    (0..m).map(|i| (wq.at2(i, j) - w.at2(i, j)) as f64).collect();
                let mut e = 0.0f64;
                for i in 0..m {
                    if d[i] == 0.0 {
                        continue;
                    }
                    let grow = g.row(i);
                    let s: f64 = (0..m).map(|t| grow[t] as f64 * d[t]).sum();
                    e += d[i] * s;
                }
                e.max(0.0)
            })
            .collect()
    }
}

/// Reference implementation of the reconstruction error straight from X
/// (used by tests to validate the Gram identity).
pub fn recon_error_from_x(x: &Tensor, w: &Tensor, wq: &Tensor) -> f64 {
    let d = matmul(x, &wq.sub(w));
    d.frob_norm_sq()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn gram_identity() {
        let mut rng = Rng::new(4);
        let (b, m, n) = (32, 10, 6);
        let x = Tensor::new(&[b, m], rng.normal_vec(b * m));
        let w = Tensor::new(&[m, n], rng.normal_vec(m * n));
        let wq = Tensor::new(&[m, n], rng.normal_vec(m * n));
        let gs = GramSet::from_features(&x);
        let e_gram = gs.recon_error(&w, &wq);
        let e_x = recon_error_from_x(&x, &w, &wq);
        assert!((e_gram - e_x).abs() < 1e-2 * e_x.max(1.0), "{e_gram} vs {e_x}");
        // per-column decomposition sums to total
        let per: f64 = gs.recon_error_per_col(&w, &wq).iter().sum();
        assert!((per - e_gram).abs() < 1e-6 * e_gram.max(1.0));
    }

    #[test]
    fn accumulate_equals_concat() {
        let mut rng = Rng::new(5);
        let (b, m) = (16, 8);
        let x1 = Tensor::new(&[b, m], rng.normal_vec(b * m));
        let x2 = Tensor::new(&[b, m], rng.normal_vec(b * m));
        let mut cat = x1.data().to_vec();
        cat.extend_from_slice(x2.data());
        let xc = Tensor::new(&[2 * b, m], cat);
        let mut g = GramSet::from_features(&x1);
        g.accumulate(&GramSet::from_features(&x2));
        let gc = GramSet::from_features(&xc);
        match (&g, &gc) {
            (GramSet::Shared(a), GramSet::Shared(b)) => {
                assert!(a.max_abs_diff(b) < 1e-3);
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn grouped_from_features() {
        let mut rng = Rng::new(6);
        let (rows, c, kk) = (20, 3, 4);
        let x3 = Tensor::new(&[rows, c, kk], rng.normal_vec(rows * c * kk));
        let gs = GramSet::from_grouped_features(&x3);
        assert!(gs.is_grouped());
        assert_eq!(gs.m(), kk);
        match &gs {
            GramSet::Grouped(groups) => {
                assert_eq!(groups.len(), c);
                // each group's Gram is PSD: diag >= 0
                for g in groups {
                    for i in 0..kk {
                        assert!(g.at2(i, i) >= 0.0);
                    }
                }
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn zero_diff_zero_error() {
        let mut rng = Rng::new(7);
        let x = Tensor::new(&[8, 4], rng.normal_vec(32));
        let w = Tensor::new(&[4, 3], rng.normal_vec(12));
        let gs = GramSet::from_features(&x);
        assert_eq!(gs.recon_error(&w, &w), 0.0);
    }
}
