//! Asymmetric uniform quantization grids (Sec. 3 preliminaries).
//!
//! A b-bit grid is the code set S = {z, z+1, ..., z + 2^b - 1} with a
//! floating-point scale δ:  w ≈ δ·q, q ∈ S. Per-layer quantization shares
//! (δ, z) across the whole weight matrix; per-channel gives every output
//! column its own pair. Codes are stored as f32 during optimization (they
//! are exact small integers) and packed to u8/bitstream for deployment.

use crate::tensor::Tensor;

/// Quantization scheme granularity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scheme {
    PerLayer,
    PerChannel,
}

impl Scheme {
    pub fn parse(s: &str) -> Option<Scheme> {
        match s {
            "per-layer" | "per_layer" | "pl" => Some(Scheme::PerLayer),
            "per-channel" | "per_channel" | "pc" => Some(Scheme::PerChannel),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Scheme::PerLayer => "per-layer",
            Scheme::PerChannel => "per-channel",
        }
    }
}

/// Full quantizer configuration (shared by COMQ and all baselines).
#[derive(Debug, Clone, Copy)]
pub struct QuantConfig {
    pub bits: u32,
    pub scheme: Scheme,
    pub order: super::OrderKind,
    /// COMQ iteration count K (paper Tab. 7: 3–4 is optimal).
    pub iters: usize,
    /// Per-channel init shrink λ (paper Tab. 10: λ<1 matters at 2-bit).
    pub lam: f32,
}

impl Default for QuantConfig {
    fn default() -> Self {
        QuantConfig {
            bits: 4,
            scheme: Scheme::PerChannel,
            order: super::OrderKind::GreedyPerColumn,
            iters: 3,
            lam: 1.0,
        }
    }
}

impl QuantConfig {
    pub fn levels(&self) -> f32 {
        (1u64 << self.bits) as f32 - 1.0
    }
}

/// Result of quantizing one layer: W_q = Q · diag(δ) with codes in
/// [zero, zero + 2^b - 1] per column.
#[derive(Debug, Clone)]
pub struct LayerQuant {
    /// Bit-codes (exact integers stored as f32), shape [m, n].
    pub q: Tensor,
    /// Per-column scales (per-layer mode stores the shared value n times).
    pub delta: Vec<f32>,
    /// Per-column zero points.
    pub zero: Vec<f32>,
}

impl LayerQuant {
    /// Reconstruct the dequantized weight W_q [m, n].
    pub fn dequant(&self) -> Tensor {
        let (m, n) = (self.q.rows(), self.q.cols());
        let mut out = Tensor::zeros(&[m, n]);
        for i in 0..m {
            let qrow = self.q.row(i);
            let orow = &mut out.data_mut()[i * n..(i + 1) * n];
            for j in 0..n {
                orow[j] = qrow[j] * self.delta[j];
            }
        }
        out
    }

    /// All codes within their column grids (invariant check).
    pub fn codes_feasible(&self, bits: u32) -> bool {
        let levels = (1u64 << bits) as f32 - 1.0;
        let n = self.q.cols();
        self.q.data().iter().enumerate().all(|(idx, &q)| {
            let j = idx % n;
            q.fract() == 0.0 && q >= self.zero[j] && q <= self.zero[j] + levels
        })
    }

    /// Pack codes into an unsigned offset-binary byte stream (b <= 8):
    /// stored value = q - zero ∈ [0, 2^b - 1], bit-packed little-endian.
    pub fn pack_codes(&self, bits: u32) -> Vec<u8> {
        assert!(bits as usize <= 8);
        let n = self.q.cols();
        let total = self.q.len();
        let mut out = vec![0u8; (total * bits as usize).div_ceil(8)];
        for (idx, &q) in self.q.data().iter().enumerate() {
            let j = idx % n;
            let u = (q - self.zero[j]) as u64 & ((1 << bits) - 1);
            let bitpos = idx * bits as usize;
            let (byte, off) = (bitpos / 8, bitpos % 8);
            out[byte] |= (u << off) as u8;
            if off + bits as usize > 8 {
                out[byte + 1] |= (u >> (8 - off)) as u8;
            }
        }
        out
    }

    /// Inverse of `pack_codes`.
    pub fn unpack_codes(packed: &[u8], bits: u32, m: usize, n: usize, zero: &[f32]) -> Tensor {
        let mut data = vec![0.0f32; m * n];
        for_each_code(packed, bits, m * n, |idx, u| {
            data[idx] = u as f32 + zero[idx % n];
        });
        Tensor::new(&[m, n], data)
    }
}

/// Walk the unsigned codes of a packed offset-binary bitstream (the
/// `pack_codes` layout): calls `f(idx, u)` for idx in 0..count. The one
/// decoder both the f32 unpack above and the i8 serving prep
/// (`serve::Int8Panel`) go through, so the bit layout lives in exactly
/// two places — pack and this.
pub(crate) fn for_each_code(packed: &[u8], bits: u32, count: usize, mut f: impl FnMut(usize, u64)) {
    assert!(bits as usize <= 8);
    let mask = (1u64 << bits) - 1;
    let bits = bits as usize;
    for idx in 0..count {
        let bitpos = idx * bits;
        let (byte, off) = (bitpos / 8, bitpos % 8);
        let mut u = (packed[byte] as u64) >> off;
        if off + bits > 8 {
            u |= (packed[byte + 1] as u64) << (8 - off);
        }
        f(idx, u & mask);
    }
}

/// Per-channel init (Sec. 3.2): δ_j = λ (max w_j - min w_j) / (2^b - 1),
/// z_j = round(min w_j / δ_j). Returns (delta, zero).
pub fn init_per_channel(w: &Tensor, bits: u32, lam: f32) -> (Vec<f32>, Vec<f32>) {
    let levels = (1u64 << bits) as f32 - 1.0;
    let (mins, maxs) = w.col_min_max();
    let mut delta = Vec::with_capacity(mins.len());
    let mut zero = Vec::with_capacity(mins.len());
    for (&mn, &mx) in mins.iter().zip(&maxs) {
        let mut d = lam * (mx - mn) / levels;
        if d <= 0.0 {
            d = 1e-8;
        }
        delta.push(d);
        zero.push((mn / d).round_ties_even());
    }
    (delta, zero)
}

/// Per-layer init (Sec. 3.1): shared δ = mean_j ||w_j||_∞ / 2^(b-1),
/// shared z = round(min W / δ). Returns (delta, zero) scalars.
pub fn init_per_layer(w: &Tensor, bits: u32) -> (f32, f32) {
    let inf = w.col_inf_norm();
    let mut d = inf.iter().sum::<f32>() / inf.len() as f32 / (1u64 << (bits - 1)) as f32;
    if d <= 0.0 {
        d = 1e-8;
    }
    let z = (w.min() / d).round_ties_even();
    (d, z)
}

/// Initialize (delta, zero) vectors per the config.
pub fn init_grid(w: &Tensor, cfg: &QuantConfig) -> (Vec<f32>, Vec<f32>) {
    match cfg.scheme {
        Scheme::PerChannel => init_per_channel(w, cfg.bits, cfg.lam),
        Scheme::PerLayer => {
            let (d, z) = init_per_layer(w, cfg.bits);
            (vec![d; w.cols()], vec![z; w.cols()])
        }
    }
}

/// clip(round(x), z, z + levels) — the scalar quantization step, with
/// ties-to-even rounding to match numpy/jnp exactly.
#[inline]
pub fn qround(x: f32, zero: f32, levels: f32) -> f32 {
    x.round_ties_even().clamp(zero, zero + levels)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn per_channel_init_covers_range() {
        let w = Tensor::new(&[3, 2], vec![-1.0, 0.0, 0.5, 2.0, 1.0, 4.0]);
        let (d, z) = init_per_channel(&w, 4, 1.0);
        // column 0: range [-1, 1], delta = 2/15
        assert!((d[0] - 2.0 / 15.0).abs() < 1e-6);
        assert!((z[0] - (-1.0 / d[0]).round_ties_even()).abs() < 1e-6);
        // column 1: range [0, 4]
        assert!((d[1] - 4.0 / 15.0).abs() < 1e-6);
    }

    #[test]
    fn degenerate_column_guard() {
        let w = Tensor::new(&[2, 1], vec![3.0, 3.0]); // zero range
        let (d, _z) = init_per_channel(&w, 4, 1.0);
        assert!(d[0] > 0.0);
        let (d2, _) = init_per_layer(&Tensor::zeros(&[2, 2]), 4);
        assert!(d2 > 0.0);
    }

    #[test]
    fn qround_ties_even() {
        assert_eq!(qround(0.5, -10.0, 20.0), 0.0); // ties to even like numpy
        assert_eq!(qround(1.5, -10.0, 20.0), 2.0);
        assert_eq!(qround(2.5, -10.0, 20.0), 2.0);
        assert_eq!(qround(100.0, 0.0, 15.0), 15.0); // clipped
        assert_eq!(qround(-3.0, 0.0, 15.0), 0.0);
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let mut rng = Rng::new(9);
        for bits in [2u32, 3, 4, 8] {
            let levels = (1u64 << bits) as f32 - 1.0;
            let (m, n) = (13, 7);
            let zero: Vec<f32> = (0..n).map(|_| (rng.below(9) as f32) - 4.0).collect();
            let mut q = Tensor::zeros(&[m, n]);
            for idx in 0..m * n {
                let j = idx % n;
                q.data_mut()[idx] = zero[j] + rng.below(levels as usize + 1) as f32;
            }
            let lq = LayerQuant { q: q.clone(), delta: vec![0.1; n], zero: zero.clone() };
            assert!(lq.codes_feasible(bits));
            let packed = lq.pack_codes(bits);
            assert_eq!(packed.len(), (m * n * bits as usize).div_ceil(8));
            let un = LayerQuant::unpack_codes(&packed, bits, m, n, &zero);
            assert_eq!(un, q, "bits={bits}");
        }
    }

    #[test]
    fn dequant_multiplies_per_column() {
        let lq = LayerQuant {
            q: Tensor::new(&[2, 2], vec![1., 2., 3., 4.]),
            delta: vec![0.5, 2.0],
            zero: vec![0.0, 0.0],
        };
        assert_eq!(lq.dequant().data(), &[0.5, 4.0, 1.5, 8.0]);
    }

    #[test]
    fn infeasible_codes_detected() {
        let lq = LayerQuant {
            q: Tensor::new(&[1, 1], vec![17.0]),
            delta: vec![1.0],
            zero: vec![0.0],
        };
        assert!(!lq.codes_feasible(4)); // 17 > 15
        let lq2 = LayerQuant {
            q: Tensor::new(&[1, 1], vec![1.5]),
            delta: vec![1.0],
            zero: vec![0.0],
        };
        assert!(!lq2.codes_feasible(4)); // non-integer
    }
}
