//! GPFQ: greedy path-following quantization (Zhang, Zhou & Saab 2023).
//!
//! A single sequential pass: each coordinate is quantized to absorb the
//! *accumulated residual* of all previously quantized coordinates,
//!
//! ```text
//!     u_0 = 0
//!     q_i = quant( ⟨x_i, w_i x_i + u_{i-1}⟩ / (δ ‖x_i‖²) )
//!     u_i = u_{i-1} + (w_i − δ q_i) x_i
//! ```
//!
//! Unlike COMQ there is no revisiting (one pass, path-following) and the
//! scale δ is fixed at init — the paper notes GPFQ needs trial-and-error
//! to pick scales, which is exactly what the tables show at low bits.
//!
//! Gram-domain: ⟨x_i, u⟩ = Σ_{t<i} r_t G_{t,i}, maintained incrementally
//! as s ← s + r_i g_i after each step (O(m) per coordinate).

use crate::tensor::Tensor;
use crate::util::pool::parallel_ranges;

use super::comq::EPS_DIAG;
use super::gram::GramSet;
use super::grid::{init_grid, qround, LayerQuant, QuantConfig};

pub fn gpfq(gram: &GramSet, w: &Tensor, cfg: &QuantConfig) -> LayerQuant {
    let (m, n) = (w.rows(), w.cols());
    assert_eq!(gram.m(), m);
    let (delta, zero) = init_grid(w, cfg);
    let levels = cfg.levels();
    let mut q = Tensor::zeros(&[m, n]);
    let q_ptr = QPtr(q.data_mut().as_mut_ptr());
    parallel_ranges(n, 4, |_, cols| {
        let mut s = vec![0.0f32; m]; // s_i = <x_i, u>
        for j in cols {
            let g = gram.for_col(j);
            let dj = delta[j];
            let zj = zero[j];
            s.iter_mut().for_each(|v| *v = 0.0);
            let qd = unsafe { std::slice::from_raw_parts_mut(q_ptr.ptr(), m * n) };
            for i in 0..m {
                let gii = g.at2(i, i);
                let wi = w.at2(i, j);
                let qv = if gii <= EPS_DIAG {
                    qround(wi / dj, zj, levels)
                } else {
                    qround((wi * gii + s[i]) / (dj * gii), zj, levels)
                };
                qd[i * n + j] = qv;
                let r = wi - dj * qv;
                if r != 0.0 {
                    let grow = g.row(i);
                    for (st, gt) in s.iter_mut().zip(grow) {
                        *st += r * gt;
                    }
                }
            }
        }
    });
    LayerQuant { q, delta, zero }
}

struct QPtr(*mut f32);
unsafe impl Send for QPtr {}
unsafe impl Sync for QPtr {}
impl QPtr {
    #[inline]
    fn ptr(&self) -> *mut f32 {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn::rtn;
    use crate::quant::{comq_gram, OrderKind, Scheme};
    use crate::util::Rng;

    fn cfg(bits: u32) -> QuantConfig {
        QuantConfig {
            bits,
            scheme: Scheme::PerChannel,
            order: OrderKind::Cyclic,
            iters: 3,
            lam: 1.0,
        }
    }

    fn setup(seed: u64) -> (Tensor, GramSet) {
        let mut rng = Rng::new(seed);
        let (b, m, n) = (96, 24, 12);
        let x = Tensor::new(&[b, m], rng.normal_vec(b * m));
        let w = Tensor::new(&[m, n], rng.normal_vec(m * n)).scale(0.4);
        (w, GramSet::from_features(&x))
    }

    #[test]
    fn beats_rtn_at_4bit() {
        let (w, g) = setup(30);
        let c = cfg(4);
        let e_gpfq = g.recon_error(&w, &gpfq(&g, &w, &c).dequant());
        let e_rtn = g.recon_error(&w, &rtn(&w, &c).dequant());
        assert!(e_gpfq < e_rtn, "gpfq {e_gpfq} vs rtn {e_rtn}");
    }

    #[test]
    fn comq_beats_gpfq_on_average() {
        // COMQ revisits coordinates and learns δ; GPFQ does neither.
        let mut tot_g = 0.0;
        let mut tot_c = 0.0;
        for seed in 0..5 {
            let (w, g) = setup(40 + seed);
            let c = cfg(3);
            tot_g += g.recon_error(&w, &gpfq(&g, &w, &c).dequant());
            tot_c += g.recon_error(&w, &comq_gram(&g, &w, &c).dequant());
        }
        assert!(tot_c < tot_g, "comq {tot_c} vs gpfq {tot_g}");
    }

    #[test]
    fn codes_feasible() {
        let (w, g) = setup(50);
        for bits in [2u32, 3, 4] {
            assert!(gpfq(&g, &w, &cfg(bits)).codes_feasible(bits));
        }
    }
}
