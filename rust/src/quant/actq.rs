//! Activation quantization (Tab. 2 / Tab. 5 "full quantization").
//!
//! Per-tensor asymmetric uniform fake-quant on each quantizable layer's
//! input, with scales calibrated from the (min, max) activation
//! statistics the calibration pass collects. A RepQ-ViT-style clipping
//! ratio tightens the range before the scale is derived (post-Softmax /
//! post-GELU tails are long; clipping them is what makes A4 usable —
//! the paper adopts [27]'s reparameterization for the same reason).

use crate::tensor::Tensor;

/// Per-layer activation quantization parameters.
#[derive(Debug, Clone, Copy)]
pub struct ActQuant {
    pub scale: f32,
    pub zero: f32,
    pub bits: u32,
}

impl ActQuant {
    /// Derive from calibrated activation range, shrinking each bound
    /// *toward zero* by `clip` (1.0 = full observed range). Shrinking
    /// toward zero — never past it — keeps exact 0 representable, which
    /// matters enormously for post-ReLU inputs where most of the mass
    /// sits at 0: clipping that moved `lo` above 0 would add a systematic
    /// DC bias to every activation (observed: resnet A8 collapsing to
    /// chance while A4 survived by a zero-point rounding accident).
    pub fn from_range(mut mn: f32, mut mx: f32, bits: u32, clip: f32) -> ActQuant {
        if !(mn.is_finite() && mx.is_finite()) || mn > mx {
            (mn, mx) = (0.0, 1.0);
        }
        let lo = if mn < 0.0 { mn * clip } else { mn };
        let hi = if mx > 0.0 { mx * clip } else { mx };
        let levels = (1u64 << bits) as f32 - 1.0;
        let mut scale = (hi - lo) / levels;
        if scale <= 0.0 {
            scale = 1e-8;
        }
        let zero = (lo / scale).round_ties_even();
        ActQuant { scale, zero, bits }
    }

    /// Derive from the observed range of one batch (dynamic
    /// quantization: the serving runtime uses this when a packed
    /// checkpoint carries no calibrated activation scales).
    pub fn from_tensor(t: &Tensor, bits: u32) -> ActQuant {
        ActQuant::from_range(t.min(), t.max(), bits, 1.0)
    }

    /// Number of representable steps minus one (2^bits − 1).
    #[inline]
    pub fn levels(&self) -> f32 {
        (1u64 << self.bits) as f32 - 1.0
    }

    /// Fake-quantize one value.
    #[inline]
    pub fn apply(&self, x: f32) -> f32 {
        let q = self.code(x) + self.zero;
        q * self.scale
    }

    /// The unsigned integer code of one value: clamp(round(x/δ) − z,
    /// 0, 2^bits − 1). `apply(x) == (code(x) + zero) * scale` exactly —
    /// the integer serving GEMM relies on this identity to reproduce the
    /// fake-quant reference in integer arithmetic.
    #[inline]
    pub fn code(&self, x: f32) -> f32 {
        let q = (x / self.scale).round_ties_even() - self.zero;
        q.clamp(0.0, self.levels())
    }

    /// Fake-quantize a tensor in place.
    pub fn apply_tensor(&self, t: &mut Tensor) {
        for x in t.data_mut() {
            *x = self.apply(*x);
        }
    }

    /// As the (scale, zero) row the PJRT actq graph expects.
    pub fn as_row(&self) -> [f32; 2] {
        [self.scale, self.zero]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn identity_on_grid_points() {
        let aq = ActQuant::from_range(0.0, 15.0, 4, 1.0);
        for v in 0..=15 {
            let x = v as f32;
            assert!((aq.apply(x) - x).abs() < 1e-5, "{x}");
        }
    }

    #[test]
    fn clips_out_of_range() {
        let aq = ActQuant::from_range(0.0, 1.0, 4, 1.0);
        assert!(aq.apply(100.0) <= 1.0 + aq.scale);
        assert!(aq.apply(-100.0) >= -aq.scale);
    }

    #[test]
    fn error_bounded_by_half_step() {
        let aq = ActQuant::from_range(-2.0, 2.0, 8, 1.0);
        let mut rng = Rng::new(1);
        for _ in 0..1000 {
            let x = rng.range_f32(-2.0, 2.0);
            assert!((aq.apply(x) - x).abs() <= aq.scale * 0.5 + 1e-6);
        }
    }

    #[test]
    fn degenerate_range_guarded() {
        let aq = ActQuant::from_range(3.0, 3.0, 4, 1.0);
        assert!(aq.scale > 0.0);
        assert!(aq.apply(3.0).is_finite());
        let aq2 = ActQuant::from_range(f32::NAN, 1.0, 4, 1.0);
        assert!(aq2.apply(0.5).is_finite());
    }

    #[test]
    fn clipping_tightens_scale() {
        let full = ActQuant::from_range(-10.0, 10.0, 4, 1.0);
        let clipped = ActQuant::from_range(-10.0, 10.0, 4, 0.5);
        assert!(clipped.scale < full.scale);
    }

    #[test]
    fn code_identity_matches_apply() {
        let aq = ActQuant::from_range(-3.0, 5.0, 8, 0.95);
        let mut rng = Rng::new(11);
        for _ in 0..500 {
            let x = rng.range_f32(-4.0, 6.0);
            let c = aq.code(x);
            assert!(c.fract() == 0.0 && c >= 0.0 && c <= aq.levels(), "{c}");
            assert_eq!((c + aq.zero) * aq.scale, aq.apply(x));
        }
        let dynq = ActQuant::from_tensor(&Tensor::from_vec(vec![-1.0, 0.5, 2.0]), 4);
        assert!(dynq.scale > 0.0);
        assert_eq!(dynq.bits, 4);
    }

    #[test]
    fn tensor_apply_matches_scalar() {
        let aq = ActQuant::from_range(-1.0, 1.0, 4, 0.9);
        let mut rng = Rng::new(2);
        let v = rng.normal_vec(64);
        let mut t = Tensor::from_vec(v.clone());
        aq.apply_tensor(&mut t);
        for (a, b) in t.data().iter().zip(&v) {
            assert_eq!(*a, aq.apply(*b));
        }
    }
}
