//! Column-major sweep workspace: the production COMQ engine.
//!
//! `comq_gram` (quant/comq.rs) walks columns of row-major W/Q, so every
//! column visit pays stride-`n` gathers of W, Q and diag(G) into scratch,
//! a stride-`n` scatter of Q back, and — for the greedy orders — a fresh
//! score pass + argsort per column *per sweep*, even though the greedy
//! scores depend only on diag(G) and |W|, which never change between
//! sweeps. This engine removes all of that by packing the layer into a
//! [`SweepWorkspace`] once:
//!
//! * **Wᵀ, Qᵀ [n, m]** — every column of W/Q is a contiguous slice; the
//!   inner coordinate loop runs gather/scatter-free, and the batched
//!   panels below come out already column-major.
//! * **diag(G)** — packed once (shared Grams; grouped layers gather per
//!   column, which is unavoidable since each column has its own Gram).
//! * **order plan** — cyclic/shared orders are one vector; greedy
//!   per-column orders are one [n × m] u32 table computed once per layer
//!   (in parallel) and reused across all `cfg.iters` sweeps. The table
//!   costs the footprint of one extra weight matrix, which is the price
//!   of turning K·n argsorts into n.
//! * **Pᵀ = Rᵀ·G and (G·Q)ᵀ = Qᵀ·G panels** — the two batched products
//!   (≥2/3 of sweep FLOPs) run through the register-tiled matmul against
//!   a G packed into B-strips once per layer (not once per product) and
//!   land directly in column-major layout: no per-column panel
//!   extraction, no transpose per sweep.
//!
//! One transpose in, one transpose out, per layer.
//!
//! ## Bit-identity contract
//!
//! The codes and scales are **bit-identical** to `comq_gram` (tests
//! enforce it). Three ingredients make that hold:
//!
//! 1. the per-coordinate update is the literal same function
//!    (`update_column` in comq.rs), fed the same values;
//! 2. the batched panels are computed as `Rᵀ·G` / `Qᵀ·G` instead of
//!    `(G·R)` / `(G·Q)` — with a bit-symmetric G (all `GramSet`
//!    constructors mirror exactly) and the skip-free, k-sequential
//!    matmul kernel, the transposed product is the same sequence of
//!    commuted multiplications, hence the same f32 sums;
//! 3. greedy orders are computed by the same scoring/argsort code, and
//!    reusing them across sweeps is exact because the scores are
//!    sweep-invariant.

use crate::tensor::{matmul_into_packed, pack_b, Tensor};
use crate::util::pool::{parallel_ranges, SendPtr};

use super::comq::{gemv, gemv_diff, update_column};
use super::gram::GramSet;
use super::grid::{init_grid, LayerQuant, QuantConfig, Scheme};
use super::order::{order_for_column_into, shared_order, OrderKind};

/// Coordinate-update order plan, fixed for the whole layer.
enum OrderPlan {
    /// One order shared by every column (cyclic, or greedy-shared over a
    /// shared Gram).
    Uniform(Vec<u32>),
    /// Per-column orders, column j at `[j*m .. (j+1)*m]`.
    Table(Vec<u32>),
}

impl OrderPlan {
    #[inline]
    fn col(&self, j: usize, m: usize) -> &[u32] {
        match self {
            OrderPlan::Uniform(o) => o,
            OrderPlan::Table(t) => &t[j * m..(j + 1) * m],
        }
    }
}

/// The packed per-layer state: everything the sweeps touch, laid out
/// column-major, built once per `comq_workspace` call.
struct SweepWorkspace {
    m: usize,
    n: usize,
    /// Wᵀ [n, m].
    wt: Vec<f32>,
    /// Qᵀ [n, m] (codes as f32, infeasible float start).
    qt: Vec<f32>,
    /// diag(G) for shared Grams (grouped layers gather per column).
    diag: Option<Vec<f32>>,
    plan: OrderPlan,
    /// G packed into matmul B-strips once per layer (shared Grams only);
    /// both batched products per sweep reuse it instead of re-packing.
    /// Costs one extra Gram-sized buffer.
    gp: Vec<f32>,
    /// Rᵀ / Pᵀ / (GQ)ᵀ panels, reused every sweep (shared Grams only).
    rt: Vec<f32>,
    pt: Vec<f32>,
    gqt: Vec<f32>,
}

impl SweepWorkspace {
    fn pack(gram: &GramSet, w: &Tensor, cfg: &QuantConfig, delta: &[f32]) -> SweepWorkspace {
        let (m, n) = (w.rows(), w.cols());
        let wt = w.transpose2().into_data();
        // infeasible float start Q0 = W / δ, same scalar op as comq_gram
        let mut qt = vec![0.0f32; n * m];
        for j in 0..n {
            let dj = delta[j];
            let (wc, qc) = (&wt[j * m..(j + 1) * m], &mut qt[j * m..(j + 1) * m]);
            for i in 0..m {
                qc[i] = wc[i] / dj;
            }
        }
        let diag: Option<Vec<f32>> = match gram {
            GramSet::Shared(g) => Some((0..m).map(|i| g.at2(i, i)).collect()),
            GramSet::Grouped(_) => None,
        };
        let plan = match cfg.order {
            OrderKind::Cyclic => OrderPlan::Uniform((0..m as u32).collect()),
            OrderKind::GreedyShared => match &diag {
                Some(d) => OrderPlan::Uniform(shared_order(d, w)),
                None => OrderPlan::Table(order_table(gram, w, cfg.order, None)),
            },
            OrderKind::GreedyPerColumn => {
                OrderPlan::Table(order_table(gram, w, cfg.order, diag.as_deref()))
            }
        };
        let (panel, gp) = match gram {
            GramSet::Shared(g) => (n * m, pack_b(g.data(), m, m)),
            GramSet::Grouped(_) => (0, Vec::new()),
        };
        SweepWorkspace {
            m,
            n,
            wt,
            qt,
            diag,
            plan,
            gp,
            rt: vec![0.0f32; panel],
            pt: vec![0.0f32; panel],
            gqt: vec![0.0f32; panel],
        }
    }
}

/// Per-column greedy orders for the whole layer, computed in parallel
/// with per-thread scratch (no per-column allocation). Delegates
/// scoring/argsort to `order_for_column_into` so the permutations are
/// exactly the gram engine's.
fn order_table(gram: &GramSet, w: &Tensor, kind: OrderKind, diag_shared: Option<&[f32]>) -> Vec<u32> {
    let (m, n) = (w.rows(), w.cols());
    let mut table = vec![0u32; n * m];
    let tp = SendPtr::new(table.as_mut_ptr());
    parallel_ranges(n, 8, |_, cols| {
        let mut diag_scratch = vec![0.0f32; m];
        let mut scores = Vec::new();
        let mut ord: Vec<u32> = Vec::new();
        for j in cols {
            let diag: &[f32] = match diag_shared {
                Some(d) => d,
                None => {
                    let g = gram.for_col(j);
                    for i in 0..m {
                        diag_scratch[i] = g.at2(i, i);
                    }
                    &diag_scratch
                }
            };
            order_for_column_into(kind, diag, w, j, &mut scores, &mut ord);
            let out = unsafe { std::slice::from_raw_parts_mut(tp.ptr().add(j * m), m) };
            out.copy_from_slice(&ord);
        }
    });
    table
}

/// Quantize one layer with COMQ on the column-major workspace.
/// Bit-identical codes/scales to [`super::comq::comq_gram`]; strictly
/// faster. This is what the coordinator and the quantizer registry use.
pub fn comq_workspace(gram: &GramSet, w: &Tensor, cfg: &QuantConfig) -> LayerQuant {
    let (m, n) = (w.rows(), w.cols());
    assert_eq!(gram.m(), m, "Gram dimension {} vs weight rows {m}", gram.m());
    let (mut delta, zero) = init_grid(w, cfg);
    let levels = cfg.levels();
    let mut ws = SweepWorkspace::pack(gram, w, cfg, &delta);

    // Trace-only telemetry: the per-pass reconstruction-error
    // trajectory. cw[j] = w_jᵀ G_j w_j is sweep-invariant (one extra
    // Gram product per layer, paid only under COMQ_OBS=trace); each
    // pass's error then falls out of the δ-statistics the sweep already
    // computes: ‖X(w_j − δ_j q_j)‖² = cw_j − 2δ_j·(q_jᵀG w_j) +
    // δ_j²·(q_jᵀG q_j). Observation-only — nothing here feeds back into
    // the sweep, so the bit-identity contract above is untouched.
    let cw: Option<Vec<f64>> = crate::obs::tracing().then(|| {
        let mut gw = vec![0.0f32; m];
        (0..n)
            .map(|j| {
                let wc = &ws.wt[j * m..(j + 1) * m];
                gemv(gram.for_col(j), wc, &mut gw);
                wc.iter().zip(&gw).map(|(&wi, &gi)| wi as f64 * gi as f64).sum::<f64>()
            })
            .collect()
    });
    let mut passes: Vec<f64> = Vec::new();

    let mut stats = vec![(0.0f32, 0.0f32); n];
    for _k in 0..cfg.iters {
        match gram {
            GramSet::Shared(g) => sweep_shared(g, &mut ws, &delta, &zero, levels, &mut stats),
            GramSet::Grouped(_) => sweep_grouped(gram, &mut ws, &delta, &zero, levels, &mut stats),
        }
        // -- δ-update (same scalar ops as comq_gram) --
        match cfg.scheme {
            Scheme::PerChannel => {
                for (d, nd) in delta.iter_mut().zip(&stats) {
                    if nd.1 > 0.0 {
                        *d = nd.0 / nd.1;
                    }
                }
            }
            Scheme::PerLayer => {
                let num: f64 = stats.iter().map(|p| p.0 as f64).sum();
                let den: f64 = stats.iter().map(|p| p.1 as f64).sum();
                if den > 0.0 {
                    let d = (num / den) as f32;
                    delta.iter_mut().for_each(|x| *x = d);
                }
            }
        }
        if let Some(cw) = &cw {
            // clamped at 0: each term is a true quadratic ≥ 0, but the
            // f32 stats can carry it a hair negative near convergence
            let err: f64 = (0..n)
                .map(|j| {
                    let d = delta[j] as f64;
                    (cw[j] - 2.0 * d * stats[j].0 as f64 + d * d * stats[j].1 as f64).max(0.0)
                })
                .sum();
            passes.push(err);
        }
    }
    if crate::obs::enabled() {
        crate::obs::quant::put_sweep(crate::obs::quant::SweepTelemetry {
            passes,
            updates: cfg.iters as u64 * n as u64 * m as u64,
            order_uniform: matches!(ws.plan, OrderPlan::Uniform(_)),
        });
    }
    // unpack: one transpose out
    let q = Tensor::new(&[n, m], ws.qt).transpose2();
    LayerQuant { q, delta, zero }
}

/// One sweep over a shared-Gram layer: batched panels + contiguous
/// column updates. Returns per-column (num, den) δ-statistics in
/// `stats`.
fn sweep_shared(
    g: &Tensor,
    ws: &mut SweepWorkspace,
    delta: &[f32],
    zero: &[f32],
    levels: f32,
    stats: &mut [(f32, f32)],
) {
    let (m, n) = (ws.m, ws.n);
    let diag = ws.diag.as_deref().expect("shared sweep needs packed diag");
    // Rᵀ = Wᵀ − Qᵀ·diag(δ), contiguous per column
    for j in 0..n {
        let dj = delta[j];
        let wc = &ws.wt[j * m..(j + 1) * m];
        let qc = &ws.qt[j * m..(j + 1) * m];
        let rc = &mut ws.rt[j * m..(j + 1) * m];
        for i in 0..m {
            rc[i] = wc[i] - dj * qc[i];
        }
    }
    // Pᵀ = Rᵀ·G == (G·R)ᵀ bit-for-bit (G symmetric, kernel skip-free and
    // k-sequential) — the gram engine's batched P, already column-major.
    ws.pt.fill(0.0);
    matmul_into_packed(&ws.rt, &ws.gp, &mut ws.pt, n, m, m);
    let qt_ptr = SendPtr::new(ws.qt.as_mut_ptr());
    let pt_ptr = SendPtr::new(ws.pt.as_mut_ptr());
    let wt = &ws.wt;
    let plan = &ws.plan;
    parallel_ranges(n, 4, |_, cols| {
        for j in cols {
            let wcol = &wt[j * m..(j + 1) * m];
            // columns are disjoint slices; threads own disjoint ranges
            let qcol = unsafe { std::slice::from_raw_parts_mut(qt_ptr.ptr().add(j * m), m) };
            let p = unsafe { std::slice::from_raw_parts_mut(pt_ptr.ptr().add(j * m), m) };
            update_column(g, diag, wcol, qcol, p, plan.col(j, m), delta[j], zero[j], levels);
        }
    });
    // δ-statistics: (G·Q)ᵀ = Qᵀ·G, then per-column f64 dots in the same
    // i-ascending order as the gram engine's row-major accumulation.
    ws.gqt.fill(0.0);
    matmul_into_packed(&ws.qt, &ws.gp, &mut ws.gqt, n, m, m);
    for j in 0..n {
        let gq = &ws.gqt[j * m..(j + 1) * m];
        let wc = &ws.wt[j * m..(j + 1) * m];
        let qc = &ws.qt[j * m..(j + 1) * m];
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for i in 0..m {
            num += gq[i] as f64 * wc[i] as f64;
            den += gq[i] as f64 * qc[i] as f64;
        }
        stats[j] = (num as f32, den as f32);
    }
}

/// One sweep over a grouped (depthwise) layer: each column owns its own
/// small Gram, so panels don't batch — per-column gemvs on contiguous
/// buffers, same ops as the gram engine's grouped path.
fn sweep_grouped(
    gram: &GramSet,
    ws: &mut SweepWorkspace,
    delta: &[f32],
    zero: &[f32],
    levels: f32,
    stats: &mut [(f32, f32)],
) {
    let (m, n) = (ws.m, ws.n);
    let qt_ptr = SendPtr::new(ws.qt.as_mut_ptr());
    let stats_ptr = SendPtr::new(stats.as_mut_ptr());
    let wt = &ws.wt;
    let plan = &ws.plan;
    parallel_ranges(n, 4, |_, cols| {
        let mut p = vec![0.0f32; m];
        let mut r = vec![0.0f32; m];
        let mut diag = vec![0.0f32; m];
        let mut gq = vec![0.0f32; m];
        for j in cols {
            let g = gram.for_col(j);
            for i in 0..m {
                diag[i] = g.at2(i, i);
            }
            let wcol = &wt[j * m..(j + 1) * m];
            let qcol = unsafe { std::slice::from_raw_parts_mut(qt_ptr.ptr().add(j * m), m) };
            gemv_diff(g, wcol, qcol, delta[j], &mut p, &mut r);
            update_column(g, &diag, wcol, qcol, &mut p, plan.col(j, m), delta[j], zero[j], levels);
            gemv(g, qcol, &mut gq);
            let mut num = 0.0f64;
            let mut den = 0.0f64;
            for i in 0..m {
                num += gq[i] as f64 * wcol[i] as f64;
                den += gq[i] as f64 * qcol[i] as f64;
            }
            let st = unsafe { std::slice::from_raw_parts_mut(stats_ptr.ptr(), n) };
            st[j] = (num as f32, den as f32);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::comq::comq_gram;
    use crate::quant::rtn::rtn;
    use crate::util::Rng;

    fn setup(b: usize, m: usize, n: usize, seed: u64) -> (Tensor, GramSet) {
        let mut rng = Rng::new(seed);
        let x = Tensor::new(&[b, m], rng.normal_vec(b * m));
        let w = Tensor::new(&[m, n], rng.normal_vec(m * n)).scale(0.5);
        (w, GramSet::from_features(&x))
    }

    fn assert_bit_identical(a: &LayerQuant, b: &LayerQuant, ctx: &str) {
        assert_eq!(a.q.shape(), b.q.shape(), "{ctx}: shape");
        for (i, (x, y)) in a.q.data().iter().zip(b.q.data()).enumerate() {
            assert!(x == y, "{ctx}: code {i} differs: {x} vs {y}");
        }
        for (j, (x, y)) in a.delta.iter().zip(&b.delta).enumerate() {
            assert!(x == y, "{ctx}: delta {j} differs: {x} vs {y}");
        }
        assert_eq!(a.zero, b.zero, "{ctx}: zero");
    }

    #[test]
    fn bit_identical_to_gram_engine_all_modes() {
        // the ISSUE acceptance grid: bits × schemes × orders
        let (w, g) = setup(64, 24, 12, 10);
        for bits in [2u32, 3, 4] {
            for scheme in [Scheme::PerChannel, Scheme::PerLayer] {
                for order in
                    [OrderKind::Cyclic, OrderKind::GreedyShared, OrderKind::GreedyPerColumn]
                {
                    let cfg = QuantConfig { bits, scheme, order, iters: 3, lam: 1.0 };
                    let a = comq_gram(&g, &w, &cfg);
                    let b = comq_workspace(&g, &w, &cfg);
                    assert_bit_identical(&a, &b, &format!("bits={bits} {scheme:?} {order:?}"));
                }
            }
        }
    }

    #[test]
    fn bit_identical_on_grouped_layers() {
        let mut rng = Rng::new(13);
        let (rows, c, kk) = (40, 6, 9);
        let x3 = Tensor::new(&[rows, c, kk], rng.normal_vec(rows * c * kk));
        let g = GramSet::from_grouped_features(&x3);
        let w = Tensor::new(&[kk, c], rng.normal_vec(kk * c)).scale(0.3);
        for order in [OrderKind::Cyclic, OrderKind::GreedyShared, OrderKind::GreedyPerColumn] {
            let cfg = QuantConfig { bits: 4, order, ..Default::default() };
            let a = comq_gram(&g, &w, &cfg);
            let b = comq_workspace(&g, &w, &cfg);
            assert_bit_identical(&a, &b, &format!("grouped {order:?}"));
        }
    }

    #[test]
    fn bit_identical_with_dead_features() {
        // zeroed feature column => zero Gram row/col => EPS_DIAG fallback
        let mut rng = Rng::new(14);
        let (b, m, n) = (32, 10, 4);
        let mut xd = rng.normal_vec(b * m);
        for r in 0..b {
            xd[r * m + 3] = 0.0;
        }
        let x = Tensor::new(&[b, m], xd);
        let g = GramSet::from_features(&x);
        let w = Tensor::new(&[m, n], rng.normal_vec(m * n));
        let cfg = QuantConfig::default();
        let a = comq_gram(&g, &w, &cfg);
        let bq = comq_workspace(&g, &w, &cfg);
        assert_bit_identical(&a, &bq, "dead features");
        assert!(bq.q.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn beats_rtn() {
        let (w, g) = setup(128, 32, 16, 11);
        for bits in [2u32, 3, 4] {
            let cfg = QuantConfig { bits, ..Default::default() };
            let lq = comq_workspace(&g, &w, &cfg);
            assert!(lq.codes_feasible(bits));
            let e_comq = g.recon_error(&w, &lq.dequant());
            let e_rtn = g.recon_error(&w, &rtn(&w, &cfg).dequant());
            assert!(e_comq < e_rtn, "bits={bits}: {e_comq} vs {e_rtn}");
        }
    }

    #[test]
    fn trace_trajectory_matches_exact_recon_error() {
        // Under COMQ_OBS=trace the sweep stashes a per-pass error
        // trajectory; it must be monotone non-increasing and its final
        // point must agree with the exact recon error of the result.
        crate::obs::set_level(crate::obs::ObsLevel::Trace);
        let (w, g) = setup(64, 24, 12, 15);
        let cfg = QuantConfig { bits: 2, iters: 4, ..Default::default() };
        let _ = crate::obs::quant::take_sweep(); // stale-stash guard
        let lq = comq_workspace(&g, &w, &cfg);
        let t = crate::obs::quant::take_sweep().expect("sweep telemetry at trace");
        crate::obs::set_level(crate::obs::ObsLevel::On);
        assert_eq!(t.passes.len(), 4);
        assert_eq!(t.updates, 4 * 24 * 12);
        assert!(t.order_uniform, "cyclic order is a uniform plan");
        for win in t.passes.windows(2) {
            assert!(
                win[1] <= win[0] * (1.0 + 1e-4) + 1e-9,
                "coordinate descent must not increase the error: {:?}",
                t.passes
            );
        }
        let exact = g.recon_error(&w, &lq.dequant());
        let last = *t.passes.last().unwrap();
        assert!(
            (last - exact).abs() <= 0.05 * exact.max(1e-9),
            "trajectory end {last} vs exact recon error {exact}"
        );
    }

    #[test]
    fn single_column_and_single_row_edges() {
        for &(m, n) in &[(1usize, 4usize), (8, 1), (1, 1)] {
            let mut rng = Rng::new(21);
            let x = Tensor::new(&[16, m], rng.normal_vec(16 * m));
            let w = Tensor::new(&[m, n], rng.normal_vec(m * n));
            let g = GramSet::from_features(&x);
            let cfg = QuantConfig { iters: 2, ..Default::default() };
            let a = comq_gram(&g, &w, &cfg);
            let b = comq_workspace(&g, &w, &cfg);
            assert_bit_identical(&a, &b, &format!("edge ({m},{n})"));
        }
    }
}
