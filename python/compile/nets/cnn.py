"""Tiny CNN family (raw JAX, build-time only).

Stand-ins for the paper's ResNet18 / ResNet50 / MobileNetV2:

  * ``resnet_lite``   — stem conv + 3 residual stages (2 blocks each),
                        global-average-pool, fc head;
  * ``cnn_s``         — plain VGG-ish conv stack;
  * ``mobilenet_lite``— depthwise-separable blocks (dw 3x3 + pw 1x1),
                        exercising the *grouped* Gram path of the
                        quantizers.

All convolutions are explicit im2col + matmul (patch order kh, kw, cin)
so the Rust native forward (rust/src/model/cnn.rs) is an exact mirror.
No batch-norm: blocks use a residual structure + He init, which trains
fine at this depth and keeps inference-graph parity trivial.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from .common import Tap, add_linear, conv2d, dwconv2d, he_init, register

IMG = 16
NUM_CLASSES = 16


def relu(x):
    return jnp.maximum(x, 0.0)


def avgpool2(x):
    """2x2 average pooling, stride 2 (NHWC)."""
    b, h, w, c = x.shape
    return jnp.mean(x.reshape(b, h // 2, 2, w // 2, 2, c), axis=(2, 4))


@dataclass(frozen=True)
class CNNConfig:
    name: str
    kind: str  # "resnet" | "plain" | "mobile"
    width: int
    blocks: int = 2  # residual blocks per stage (resnet)
    img: int = IMG
    classes: int = NUM_CLASSES


# ---------------------------------------------------------------------------
# resnet_lite
# ---------------------------------------------------------------------------


def _resnet_init(cfg: CNNConfig, seed: int):
    rng = np.random.default_rng(seed)
    p: dict[str, np.ndarray] = {}
    w = cfg.width
    add_linear(p, rng, "stem", 3 * 3 * 3, w, he_init)
    cin = w
    for s in range(3):
        cout = w * (2**s)
        for b in range(cfg.blocks):
            nm = f"s{s}/b{b}"
            add_linear(p, rng, f"{nm}/conv1", 3 * 3 * cin, cout, he_init)
            add_linear(p, rng, f"{nm}/conv2", 3 * 3 * cout, cout, he_init)
            if cin != cout:
                add_linear(p, rng, f"{nm}/skip", cin, cout, he_init)
            cin = cout
    add_linear(p, rng, "head", cin, cfg.classes, he_init)
    return p


def _resnet_forward(cfg: CNNConfig, params, x, tap: Tap):
    h = relu(conv2d(params, "stem", x, 3, 1, 1, tap))
    cin = cfg.width
    for s in range(3):
        cout = cfg.width * (2**s)
        for b in range(cfg.blocks):
            nm = f"s{s}/b{b}"
            stride = 2 if (s > 0 and b == 0) else 1
            y = relu(conv2d(params, f"{nm}/conv1", h, 3, stride, 1, tap))
            y = conv2d(params, f"{nm}/conv2", y, 3, 1, 1, tap)
            if cin != cout:
                # 1x1 projection shortcut (strided)
                sk = h[:, ::stride, ::stride, :]
                bsz, oh, ow, _ = sk.shape
                from .common import linear

                sk = linear(params, f"{nm}/skip", sk.reshape(bsz * oh * ow, cin), tap)
                sk = sk.reshape(bsz, oh, ow, cout)
            else:
                sk = h if stride == 1 else h[:, ::stride, ::stride, :]
            h = relu(y + sk)
            cin = cout
    pooled = jnp.mean(h, axis=(1, 2))
    from .common import linear

    return linear(params, "head", pooled, tap)


def _resnet_layers(cfg: CNNConfig) -> list[str]:
    names = ["stem"]
    cin = cfg.width
    for s in range(3):
        cout = cfg.width * (2**s)
        for b in range(cfg.blocks):
            nm = f"s{s}/b{b}"
            names += [f"{nm}/conv1", f"{nm}/conv2"]
            if cin != cout:
                names.append(f"{nm}/skip")
            cin = cout
    names.append("head")
    return names


# ---------------------------------------------------------------------------
# cnn_s (plain)
# ---------------------------------------------------------------------------


def _plain_init(cfg: CNNConfig, seed: int):
    rng = np.random.default_rng(seed)
    p: dict[str, np.ndarray] = {}
    w = cfg.width
    add_linear(p, rng, "conv0", 3 * 3 * 3, w, he_init)
    add_linear(p, rng, "conv1", 3 * 3 * w, w, he_init)
    add_linear(p, rng, "conv2", 3 * 3 * w, 2 * w, he_init)
    add_linear(p, rng, "conv3", 3 * 3 * 2 * w, 2 * w, he_init)
    add_linear(p, rng, "conv4", 3 * 3 * 2 * w, 4 * w, he_init)
    add_linear(p, rng, "fc", 4 * w, 2 * w, he_init)
    add_linear(p, rng, "head", 2 * w, cfg.classes, he_init)
    return p


def _plain_forward(cfg: CNNConfig, params, x, tap: Tap):
    from .common import linear

    h = relu(conv2d(params, "conv0", x, 3, 1, 1, tap))
    h = relu(conv2d(params, "conv1", h, 3, 1, 1, tap))
    h = avgpool2(h)
    h = relu(conv2d(params, "conv2", h, 3, 1, 1, tap))
    h = relu(conv2d(params, "conv3", h, 3, 1, 1, tap))
    h = avgpool2(h)
    h = relu(conv2d(params, "conv4", h, 3, 1, 1, tap))
    pooled = jnp.mean(h, axis=(1, 2))
    h = relu(linear(params, "fc", pooled, tap))
    return linear(params, "head", h, tap)


def _plain_layers(cfg: CNNConfig) -> list[str]:
    return ["conv0", "conv1", "conv2", "conv3", "conv4", "fc", "head"]


# ---------------------------------------------------------------------------
# mobilenet_lite
# ---------------------------------------------------------------------------


def _mobile_init(cfg: CNNConfig, seed: int):
    rng = np.random.default_rng(seed)
    p: dict[str, np.ndarray] = {}
    w = cfg.width
    add_linear(p, rng, "stem", 3 * 3 * 3, w, he_init)
    cin = w
    for i in range(3):
        cout = w * (2**i)
        nm = f"dsb{i}"
        p[f"{nm}/dw/W"] = he_init(rng, 3 * 3, cin)
        p[f"{nm}/dw/b"] = np.zeros(cin, np.float32)
        add_linear(p, rng, f"{nm}/pw", cin, cout, he_init)
        cin = cout
    add_linear(p, rng, "head", cin, cfg.classes, he_init)
    return p


def _mobile_forward(cfg: CNNConfig, params, x, tap: Tap):
    from .common import linear

    h = relu(conv2d(params, "stem", x, 3, 2, 1, tap))
    cin = cfg.width
    for i in range(3):
        cout = cfg.width * (2**i)
        nm = f"dsb{i}"
        stride = 2 if i > 0 else 1
        h = relu(dwconv2d(params, f"{nm}/dw", h, 3, stride, 1, tap))
        bsz, oh, ow, _ = h.shape
        h = linear(params, f"{nm}/pw", h.reshape(bsz * oh * ow, cin), tap)
        h = relu(h.reshape(bsz, oh, ow, cout))
        cin = cout
    pooled = jnp.mean(h, axis=(1, 2))
    return linear(params, "head", pooled, tap)


def _mobile_layers(cfg: CNNConfig) -> list[str]:
    names = ["stem"]
    for i in range(3):
        names += [f"dsb{i}/dw", f"dsb{i}/pw"]
    names.append("head")
    return names


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

CNN_CONFIGS = {
    "resnet_lite": CNNConfig("resnet_lite", "resnet", width=16),
    "cnn_s": CNNConfig("cnn_s", "plain", width=16),
    "mobilenet_lite": CNNConfig("mobilenet_lite", "mobile", width=24),
}

_KIND = {
    "resnet": (_resnet_init, _resnet_forward, _resnet_layers),
    "plain": (_plain_init, _plain_forward, _plain_layers),
    "mobile": (_mobile_init, _mobile_forward, _mobile_layers),
}


def quant_layers(cfg: CNNConfig) -> list[str]:
    return _KIND[cfg.kind][2](cfg)


def _make(cfg: CNNConfig):
    init, fwd, _ = _KIND[cfg.kind]

    def factory():
        return (
            lambda seed: init(cfg, seed),
            lambda params, x, tap=None: fwd(cfg, params, x, tap or Tap()),
            cfg,
        )

    return factory


for _name, _cfg in CNN_CONFIGS.items():
    register(_name)(_make(_cfg))
