"""Raw-JAX model zoo (build-time only): tiny ViT and CNN families."""

from . import cnn, vit  # noqa: F401
from .common import MODEL_REGISTRY, build_model  # noqa: F401
