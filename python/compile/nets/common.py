"""Shared building blocks for the raw-JAX model zoo.

Design rules (all of them exist to keep bit-level parity with the Rust
native forward in rust/src/model/):

  * every learnable tensor lives in a flat dict ``{name: array}`` with
    '/'-separated names; the canonical parameter *order* is
    ``sorted(params)`` and is recorded in the artifact manifest so the
    Rust runtime can feed PJRT inputs positionally;
  * convolutions are expressed as explicit im2col + matmul with patch
    order (kh, kw, cin) — identical to rust/src/tensor/im2col.rs;
  * GELU uses the tanh approximation (same closed form in Rust);
  * every quantizable layer routes its 2-D input X through ``Tap`` so a
    single forward definition serves logits, calibration-statistics
    capture, and fake-quantized activation evaluation.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable

import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Tap: the instrumentation point in front of every quantizable layer.
# ---------------------------------------------------------------------------


@dataclass
class Tap:
    """Observes / rewrites the 2-D input of each quantizable layer.

    mode="none"   : identity (plain forward)
    mode="stats"  : record (G = XᵀX, min, max) per layer  -> .stats
    mode="actq"   : fake-quantize X with the per-layer (scale, zero) in
                    .act_params before the matmul (uniform b-bit grid)
    """

    mode: str = "none"
    bits: int = 4
    act_params: dict = field(default_factory=dict)  # name -> (scale, zero)
    stats: dict = field(default_factory=dict)  # name -> (G, mn, mx)
    names: list = field(default_factory=list)  # layer visit order

    def __call__(self, name: str, x2d: jnp.ndarray) -> jnp.ndarray:
        self.names.append(name)
        if self.mode == "stats":
            xf = x2d.astype(jnp.float32)
            self.stats[name] = (xf.T @ xf, jnp.min(xf), jnp.max(xf))
            return x2d
        if self.mode == "actq":
            return self._fake_quant(name, x2d)
        return x2d

    def grouped(self, name: str, x3d: jnp.ndarray) -> jnp.ndarray:
        """Grouped (depthwise) layer tap: x3d [rows, groups, kk].

        stats mode records a stacked per-group Gram [groups, kk, kk].
        """
        self.names.append(name)
        if self.mode == "stats":
            xf = x3d.astype(jnp.float32)
            g = jnp.einsum("rck,rcl->ckl", xf, xf)
            self.stats[name] = (g, jnp.min(xf), jnp.max(xf))
            return x3d
        if self.mode == "actq":
            return self._fake_quant(name, x3d)
        return x3d

    def _fake_quant(self, name: str, x):
        scale, zero = self.act_params[name]
        q = jnp.clip(jnp.round(x / scale) - zero, 0.0, 2.0**self.bits - 1.0)
        return (q + zero) * scale


# ---------------------------------------------------------------------------
# primitive ops
# ---------------------------------------------------------------------------


def gelu(x):
    """tanh-approximate GELU (mirrored exactly in Rust)."""
    c = math.sqrt(2.0 / math.pi)
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x * x * x)))


def layer_norm(x, gamma, beta, eps: float = 1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * gamma + beta


def linear(params, name, x2d, tap: Tap):
    """x2d [rows, m] @ W [m, n] + b. The tap sees the raw input."""
    x2d = tap(name, x2d)
    return x2d @ params[f"{name}/W"] + params[f"{name}/b"]


def softmax(x, axis=-1):
    x = x - jnp.max(x, axis=axis, keepdims=True)
    e = jnp.exp(x)
    return e / jnp.sum(e, axis=axis, keepdims=True)


def im2col(x, k: int, stride: int, pad: int):
    """NHWC -> [b, oh, ow, k*k*cin], patch order (kh, kw, cin).

    Mirrors rust/src/tensor/im2col.rs exactly.
    """
    b, h, w, c = x.shape
    if pad:
        x = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    oh = (h + 2 * pad - k) // stride + 1
    ow = (w + 2 * pad - k) // stride + 1
    cols = []
    for ki in range(k):
        for kj in range(k):
            cols.append(x[:, ki : ki + oh * stride : stride, kj : kj + ow * stride : stride, :])
    return jnp.concatenate(cols, axis=-1), oh, ow


def conv2d(params, name, x, k, stride, pad, tap: Tap):
    """Convolution as im2col + linear; the tap sees the im2col matrix."""
    patches, oh, ow = im2col(x, k, stride, pad)
    b = x.shape[0]
    m = patches.shape[-1]
    y = linear(params, name, patches.reshape(b * oh * ow, m), tap)
    return y.reshape(b, oh, ow, -1)


def dwconv2d(params, name, x, k, stride, pad, tap: Tap):
    """Depthwise conv: one k*k filter per channel.

    Implemented as im2col restricted per channel: X [rows, k*k] per channel
    with a block-diagonal weight; for quantization we expose it as a single
    linear layer with weight [k*k, c] applied channel-wise (each output
    channel uses only its own k*k patch block). The tap sees the full
    [rows*c, k*k] matrix so COMQ reconstructs every channel's filter from
    its own patches.
    """
    b, h, w, c = x.shape
    patches, oh, ow = im2col(x, k, stride, pad)  # [b,oh,ow,k*k*c], order (kh,kw,c)
    rows = b * oh * ow
    x3d = jnp.transpose(patches.reshape(rows, k * k, c), (0, 2, 1))  # [rows, c, k*k]
    x3d = tap.grouped(name, x3d)
    wgt = params[f"{name}/W"]  # [k*k, c]
    y = jnp.einsum("rck,kc->rc", x3d, wgt) + params[f"{name}/b"]
    return y.reshape(b, oh, ow, c)


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------


def he_init(rng: np.random.Generator, m: int, n: int) -> np.ndarray:
    return (rng.standard_normal((m, n)) * math.sqrt(2.0 / m)).astype(np.float32)


def xavier_init(rng: np.random.Generator, m: int, n: int) -> np.ndarray:
    return (rng.standard_normal((m, n)) * math.sqrt(1.0 / m)).astype(np.float32)


def add_linear(params, rng, name, m, n, init=xavier_init):
    params[f"{name}/W"] = init(rng, m, n)
    params[f"{name}/b"] = np.zeros(n, np.float32)


def add_ln(params, name, d):
    params[f"{name}/g"] = np.ones(d, np.float32)
    params[f"{name}/b"] = np.zeros(d, np.float32)


# ---------------------------------------------------------------------------
# model registry
# ---------------------------------------------------------------------------

MODEL_REGISTRY: dict[str, Callable] = {}


def register(name: str):
    def deco(fn):
        MODEL_REGISTRY[name] = fn
        return fn

    return deco


def build_model(name: str):
    """Returns (init_fn(seed)->params, forward_fn(params,x,tap)->logits, cfg)."""
    return MODEL_REGISTRY[name]()
