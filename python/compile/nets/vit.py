"""Tiny Vision-Transformer family (raw JAX, build-time only).

Scaled-down stand-ins for the paper's ViT-S / ViT-B / DeiT-S / Swin-T:
same layer types (patch-embed linear, qkv / proj / fc1 / fc2 linears,
LayerNorm, softmax attention, GELU), sized so that build-time CPU training
finishes in seconds. `swin_t` uses (shifted-)window attention over the
token grid, the structural signature of Swin.

The Rust native forward in rust/src/model/vit.rs mirrors these functions
operation-for-operation.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from .common import (
    Tap,
    add_linear,
    add_ln,
    gelu,
    im2col,
    layer_norm,
    linear,
    register,
    softmax,
    xavier_init,
)

IMG = 16
NUM_CLASSES = 16


@dataclass(frozen=True)
class ViTConfig:
    name: str
    dim: int
    depth: int
    heads: int
    mlp: int
    patch: int = 4
    window: int = 0  # 0 = global attention; >0 = Swin-style windows
    img: int = IMG
    classes: int = NUM_CLASSES

    @property
    def grid(self) -> int:
        return self.img // self.patch

    @property
    def tokens(self) -> int:
        return self.grid * self.grid

    @property
    def head_dim(self) -> int:
        return self.dim // self.heads


def init_params(cfg: ViTConfig, seed: int) -> dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    p: dict[str, np.ndarray] = {}
    add_linear(p, rng, "embed/proj", cfg.patch * cfg.patch * 3, cfg.dim)
    p["embed/pos"] = (0.02 * rng.standard_normal((cfg.tokens, cfg.dim))).astype(np.float32)
    for i in range(cfg.depth):
        b = f"blk{i}"
        add_ln(p, f"{b}/ln1", cfg.dim)
        add_linear(p, rng, f"{b}/qkv", cfg.dim, 3 * cfg.dim)
        add_linear(p, rng, f"{b}/proj", cfg.dim, cfg.dim)
        add_ln(p, f"{b}/ln2", cfg.dim)
        add_linear(p, rng, f"{b}/fc1", cfg.dim, cfg.mlp)
        add_linear(p, rng, f"{b}/fc2", cfg.mlp, cfg.dim)
    add_ln(p, "norm", cfg.dim)
    add_linear(p, rng, "head", cfg.dim, cfg.classes)
    return p


def _attention(cfg: ViTConfig, params, name: str, x, tap: Tap):
    """x: [b, t, d] -> [b, t, d] (global multi-head self-attention)."""
    b, t, d = x.shape
    qkv = linear(params, f"{name}/qkv", x.reshape(b * t, d), tap).reshape(b, t, 3, cfg.heads, cfg.head_dim)
    q = jnp.transpose(qkv[:, :, 0], (0, 2, 1, 3))  # [b, h, t, hd]
    k = jnp.transpose(qkv[:, :, 1], (0, 2, 1, 3))
    v = jnp.transpose(qkv[:, :, 2], (0, 2, 1, 3))
    att = softmax(q @ jnp.swapaxes(k, -1, -2) / math.sqrt(cfg.head_dim))
    out = jnp.transpose(att @ v, (0, 2, 1, 3)).reshape(b, t, d)
    return linear(params, f"{name}/proj", out.reshape(b * t, d), tap).reshape(b, t, d)


def _window_partition(x, g: int, w: int):
    """[b, g*g, d] -> [b * (g/w)^2, w*w, d]"""
    b, t, d = x.shape
    x = x.reshape(b, g // w, w, g // w, w, d)  # rows split then cols split
    x = jnp.transpose(x.reshape(b, g // w, w, g // w, w, d), (0, 1, 3, 2, 4, 5))
    return x.reshape(b * (g // w) * (g // w), w * w, d)


def _window_merge(x, b: int, g: int, w: int):
    nw = g // w
    d = x.shape[-1]
    x = x.reshape(b, nw, nw, w, w, d)
    x = jnp.transpose(x, (0, 1, 3, 2, 4, 5))
    return x.reshape(b, g * g, d)


def _shift(x, g: int, s: int):
    """Cyclic spatial shift of the token grid by s (Swin SW-MSA)."""
    b, t, d = x.shape
    xi = x.reshape(b, g, g, d)
    xi = jnp.roll(xi, (-s, -s), axis=(1, 2))
    return xi.reshape(b, t, d)


def forward(cfg: ViTConfig, params, x, tap: Tap | None = None):
    """x: [b, img, img, 3] NHWC -> logits [b, classes]."""
    tap = tap or Tap()
    b = x.shape[0]
    patches, oh, ow = im2col(x, cfg.patch, cfg.patch, 0)
    t = oh * ow
    h = linear(params, "embed/proj", patches.reshape(b * t, -1), tap).reshape(b, t, cfg.dim)
    h = h + params["embed/pos"]
    for i in range(cfg.depth):
        nm = f"blk{i}"
        a_in = layer_norm(h, params[f"{nm}/ln1/g"], params[f"{nm}/ln1/b"])
        if cfg.window:
            shift = (cfg.window // 2) if (i % 2 == 1) else 0
            a = _shift(a_in, cfg.grid, shift) if shift else a_in
            a = _window_partition(a, cfg.grid, cfg.window)
            a = _attention(cfg, params, nm, a, tap)
            a = _window_merge(a, b, cfg.grid, cfg.window)
            a = _shift(a, cfg.grid, -shift) if shift else a
        else:
            a = _attention(cfg, params, nm, a_in, tap)
        h = h + a
        m_in = layer_norm(h, params[f"{nm}/ln2/g"], params[f"{nm}/ln2/b"])
        m = linear(params, f"{nm}/fc1", m_in.reshape(b * t, cfg.dim), tap)
        m = gelu(m)
        m = linear(params, f"{nm}/fc2", m, tap).reshape(b, t, cfg.dim)
        h = h + m
    h = layer_norm(h, params["norm/g"], params["norm/b"])
    pooled = jnp.mean(h, axis=1)  # mean pool (no cls token)
    return linear(params, "head", pooled, tap)


def quant_layers(cfg: ViTConfig) -> list[str]:
    """Names of quantizable (linear) layers in forward-visit order."""
    names = ["embed/proj"]
    for i in range(cfg.depth):
        names += [f"blk{i}/qkv", f"blk{i}/proj", f"blk{i}/fc1", f"blk{i}/fc2"]
    names.append("head")
    return names


def _make(cfg: ViTConfig):
    def factory():
        return (
            lambda seed: init_params(cfg, seed),
            lambda params, x, tap=None: forward(cfg, params, x, tap),
            cfg,
        )

    return factory


VIT_CONFIGS = {
    "vit_s": ViTConfig("vit_s", dim=96, depth=4, heads=3, mlp=192),
    "vit_b": ViTConfig("vit_b", dim=192, depth=6, heads=6, mlp=384),
    "deit_s": ViTConfig("deit_s", dim=128, depth=5, heads=4, mlp=256),
    "swin_t": ViTConfig("swin_t", dim=96, depth=4, heads=3, mlp=192, window=2),
    "swin_s": ViTConfig("swin_s", dim=128, depth=6, heads=4, mlp=256, window=2),
}

for _name, _cfg in VIT_CONFIGS.items():
    register(_name)(_make(_cfg))
