"""L2: the JAX graphs that get AOT-lowered to HLO text for the Rust runtime.

Three graph families per model (all lowered with a *fixed* batch size and
positional parameters in sorted-name order, recorded in the manifest):

  * ``forward``      (params..., x)            -> logits
  * ``forward_actq`` (params..., actq, x)      -> logits with b-bit
                     fake-quantized activations at every quantizable
                     layer input (actq is [L, 2] = (scale, zero) rows)
  * ``calib_stats``  (params..., x)            -> per-layer
                     (G = XᵀX, min, max) sufficient statistics; the whole
                     COMQ objective depends on X only through G, so the
                     coordinator never materializes raw activations.

Plus the shape-specialized COMQ sweep graphs (``sweep_fn``) that embed the
L1 Pallas kernel: (G, W, Q, delta, z) -> (Q', delta') for one coordinate-
descent sweep + scale update.

HLO *text* is the interchange format (not serialized protos) — see
/opt/xla-example/README.md: jax >= 0.5 emits 64-bit instruction ids that
xla_extension 0.5.1 rejects; the text parser reassigns ids.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .kernels import comq_pallas as cp
from .nets import build_model
from .nets.common import Tap


def param_order(params: dict) -> list[str]:
    """Canonical positional order for AOT parameter passing."""
    return sorted(params)


def pack_params(params: dict) -> list:
    return [params[k] for k in param_order(params)]


def unpack_params(names: list[str], flat) -> dict:
    return dict(zip(names, flat))


# ---------------------------------------------------------------------------
# graph builders
# ---------------------------------------------------------------------------


def make_forward(model_name: str, names: list[str]):
    _, fwd, _ = build_model(model_name)

    def forward(*args):
        # args = (*params, x)
        params = unpack_params(names, args[:-1])
        return (fwd(params, args[-1], Tap()),)

    return forward


def make_forward_actq(model_name: str, names: list[str], layers: list[str], bits: int):
    _, fwd, _ = build_model(model_name)

    def forward(*args):
        # args = (*params, actq [L, 2], x)
        params = unpack_params(names, args[:-2])
        actq, x = args[-2], args[-1]
        tap = Tap(mode="actq", bits=bits)
        tap.act_params = {nm: (actq[i, 0], actq[i, 1]) for i, nm in enumerate(layers)}
        return (fwd(params, x, tap),)

    return forward


def make_calib_stats(model_name: str, names: list[str], layers: list[str]):
    _, fwd, _ = build_model(model_name)

    def stats(*args):
        params = unpack_params(names, args[:-1])
        tap = Tap(mode="stats")
        logits = fwd(params, args[-1], tap)
        outs = []
        for nm in layers:
            g, mn, mx = tap.stats[nm]
            outs += [g, mn, mx]
        # Anchor: depend on the logits so XLA cannot dead-code-eliminate
        # tail parameters (head/W, head/b) from the program signature —
        # the PJRT caller always feeds the full positional parameter list.
        outs.append(jnp.sum(logits) * 0.0)
        return tuple(outs)

    return stats


def make_sweep(per_channel: bool):
    """(G, W, Q, delta, lo, hi) -> (Q', delta'): one sweep + scale update.

    Clip bounds are runtime inputs so one artifact per (shape, mode)
    serves every bit-width.
    """

    def sweep(g, w, q, delta, lo, hi):
        q2 = cp.comq_sweep(g, w, q, delta, lo, hi)
        if per_channel:
            d2 = cp.delta_update_per_channel(g, w, q2, delta)
        else:
            d = cp.delta_update_per_layer(g, w, q2, delta[0])
            d2 = jnp.full_like(delta, d)
        return q2, d2

    return sweep


# ---------------------------------------------------------------------------
# lowering
# ---------------------------------------------------------------------------


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_to_text(fn, example_args) -> str:
    lowered = jax.jit(fn).lower(*example_args)
    return to_hlo_text(lowered)
