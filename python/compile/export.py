"""CTS ("Comq Tensor Store") — the python→rust interchange format.

A deliberately minimal, seekable binary container (little-endian):

    magic  b"CTS1"
    u32    tensor count
    per tensor:
        u16  name length, then name bytes (utf-8)
        u8   dtype   (0 = f32, 1 = i32)
        u8   ndim
        u32  dims[ndim]
        raw  data (dtype, C-contiguous, little-endian)

Mirrored by rust/src/tensorstore/. Property-tested on both sides.
"""

from __future__ import annotations

import struct

import numpy as np

MAGIC = b"CTS1"
DTYPES = {np.dtype(np.float32): 0, np.dtype(np.int32): 1}
DTYPES_INV = {0: np.float32, 1: np.int32}


def write_cts(path: str, tensors: dict[str, np.ndarray]) -> None:
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors.items():
            arr = np.ascontiguousarray(arr)
            if arr.dtype not in DTYPES:
                if np.issubdtype(arr.dtype, np.floating):
                    arr = arr.astype(np.float32)
                elif np.issubdtype(arr.dtype, np.integer):
                    arr = arr.astype(np.int32)
                else:
                    raise TypeError(f"{name}: unsupported dtype {arr.dtype}")
            nb = name.encode("utf-8")
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", DTYPES[arr.dtype], arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.tobytes(order="C"))


def read_cts(path: str) -> dict[str, np.ndarray]:
    with open(path, "rb") as f:
        assert f.read(4) == MAGIC, f"{path}: bad magic"
        (count,) = struct.unpack("<I", f.read(4))
        out: dict[str, np.ndarray] = {}
        for _ in range(count):
            (nlen,) = struct.unpack("<H", f.read(2))
            name = f.read(nlen).decode("utf-8")
            dt, ndim = struct.unpack("<BB", f.read(2))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim)) if ndim else ()
            dtype = np.dtype(DTYPES_INV[dt])
            n = int(np.prod(dims)) if ndim else 1
            data = np.frombuffer(f.read(n * dtype.itemsize), dtype=dtype)
            out[name] = data.reshape(dims).copy()
        return out
