"""SynthImageNet: a deterministic synthetic image-classification dataset.

The COMQ paper calibrates and evaluates on ImageNet-1k. ImageNet is not
available in this environment, so we substitute a seeded synthetic dataset
with the properties PTQ actually depends on:

  * a *trained* model produces the calibration features X  (the models in
    nets/ are trained on this dataset at build time, see train.py);
  * classes are separable but non-trivial (additive noise, random shifts,
    flips, per-sample contrast jitter), so the FP model sits well below
    100% accuracy and quantization damage is measurable;
  * image statistics are stationary between the calibration and validation
    splits, as with ImageNet train/val.

Each of the 16 classes is defined by a fixed class prototype: a smoothed
random RGB field plus a class-specific 2-D sinusoidal grating (orientation
and frequency indexed by the class id). Samples are prototype + jitter.

Everything is generated with numpy from fixed seeds: the dataset is
byte-for-byte reproducible across runs, which makes the accuracy numbers in
EXPERIMENTS.md reproducible too.
"""

from __future__ import annotations

import numpy as np

IMG = 16
CHANNELS = 3
NUM_CLASSES = 16


def _smooth(img: np.ndarray, rng: np.random.Generator) -> np.ndarray:
    """Cheap separable box-blur smoothing (3 passes ~= Gaussian)."""
    out = img
    for _ in range(3):
        out = (np.roll(out, 1, axis=0) + out + np.roll(out, -1, axis=0)) / 3.0
        out = (np.roll(out, 1, axis=1) + out + np.roll(out, -1, axis=1)) / 3.0
    return out


def class_prototypes(seed: int = 0) -> np.ndarray:
    """[NUM_CLASSES, IMG, IMG, 3] float32 prototypes in roughly [-1, 1]."""
    rng = np.random.default_rng(seed)
    yy, xx = np.meshgrid(np.arange(IMG), np.arange(IMG), indexing="ij")
    protos = np.zeros((NUM_CLASSES, IMG, IMG, CHANNELS), np.float32)
    for c in range(NUM_CLASSES):
        base = _smooth(rng.standard_normal((IMG, IMG, CHANNELS)).astype(np.float32), rng)
        theta = np.pi * (c % 8) / 8.0
        freq = 2.0 * np.pi * (2 + c // 8) / IMG
        grating = np.sin(freq * (np.cos(theta) * xx + np.sin(theta) * yy))
        phase = np.cos(freq * 1.7 * (np.cos(theta + 0.9) * xx + np.sin(theta + 0.9) * yy))
        pat = 0.9 * base + 0.8 * grating[..., None] + 0.4 * phase[..., None] * np.array(
            [1.0, -1.0, 0.5], np.float32
        )
        protos[c] = pat / (np.abs(pat).max() + 1e-6)
    return protos


def make_split(
    n: int, seed: int, noise: float = 0.55, proto_seed: int = 0
) -> tuple[np.ndarray, np.ndarray]:
    """Generate `n` samples: returns (images [n,32,32,3] f32, labels [n] i32)."""
    protos = class_prototypes(proto_seed)
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, NUM_CLASSES, size=n).astype(np.int32)
    imgs = protos[labels].copy()
    # random cyclic shifts (translation invariance pressure)
    sh = rng.integers(-2, 3, size=(n, 2))
    for i in range(n):
        imgs[i] = np.roll(imgs[i], (sh[i, 0], sh[i, 1]), axis=(0, 1))
    # random horizontal flips
    flip = rng.random(n) < 0.5
    imgs[flip] = imgs[flip, :, ::-1, :]
    # contrast jitter and additive noise
    gain = (0.8 + 0.4 * rng.random((n, 1, 1, 1))).astype(np.float32)
    imgs = imgs * gain + noise * rng.standard_normal(imgs.shape).astype(np.float32)
    return imgs.astype(np.float32), labels


def splits(
    n_train: int = 8192, n_calib: int = 2048, n_val: int = 2048, seed: int = 7
) -> dict[str, tuple[np.ndarray, np.ndarray]]:
    """The canonical train / calibration / validation splits."""
    return {
        "train": make_split(n_train, seed=seed),
        "calib": make_split(n_calib, seed=seed + 1),
        "val": make_split(n_val, seed=seed + 2),
    }
