"""L1: the COMQ coordinate-descent sweep as a Pallas kernel.

One kernel instance performs a full row sweep (the inner ``for i`` of
Alg. 1 / Alg. 2) for a *tile of output channels*, in the Gram domain:

    P = G (W - Q diag(delta))            (prologue, MXU-shaped matmul)
    for i in 0..m:                        (sequential; true data dep via P)
        r_old  = w_i - delta * q_i
        numer  = P[i,:] - G_ii r_old + G_ii w_i
        q_i    = clip(round(numer / (G_ii * delta)), z, z + 2^b - 1)
        P     += g_:,i  (outer)  (r_new - r_old)

TPU mapping (see DESIGN.md §Hardware-Adaptation): the grid is over
column tiles TN — columns are independent given delta, so each program
owns W/Q/P tiles of shape [m, TN] in VMEM plus the shared G panel
[m, m]; the i-loop is VPU-bound rank-1 updates, the prologue runs on the
MXU. Greedy ordering is handled by pre-permuting G and W outside the
kernel (shared order), exactly as the paper describes ("permute ...
followed by the quantization process ... then inverse permutations").

interpret=True everywhere: this repository runs on the CPU PJRT plugin;
a real-TPU build would only flip that flag.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EPS_DIAG = 1e-12
DEFAULT_TILE = 128


def _sweep_kernel(g_ref, w_ref, q_ref, delta_ref, lo_ref, hi_ref, qout_ref, *, m: int):
    """One full COMQ row sweep over an [m, TN] column tile.

    Clip bounds (lo = z, hi = z + 2^b - 1) are runtime inputs, so a single
    lowered artifact serves every bit-width for a given layer shape.
    """
    g = g_ref[...]  # [m, m] shared Gram panel
    w = w_ref[...]  # [m, TN]
    q = q_ref[...]  # [m, TN] current bit-codes (float storage)
    delta = delta_ref[...]  # [TN]
    lo = lo_ref[...]  # [TN]
    hi = hi_ref[...]  # [TN]
    diag = jnp.diag(g)  # [m]

    # Prologue: residual statistics P = G (W - Q diag(delta)).  MXU matmul.
    p = jnp.dot(g, w - q * delta[None, :], preferred_element_type=jnp.float32)

    def body(i, carry):
        p, q = carry
        w_row = jax.lax.dynamic_slice_in_dim(w, i, 1, 0)[0]  # [TN]
        q_row = jax.lax.dynamic_slice_in_dim(q, i, 1, 0)[0]
        p_row = jax.lax.dynamic_slice_in_dim(p, i, 1, 0)[0]
        dg = jax.lax.dynamic_index_in_dim(diag, i, 0, keepdims=False)  # scalar
        g_col = jax.lax.dynamic_slice_in_dim(g, i, 1, 1)[:, 0]  # [m]

        r_old = w_row - delta * q_row
        numer = p_row - dg * r_old + dg * w_row
        safe_dg = jnp.maximum(dg, EPS_DIAG)
        q_cd = jnp.clip(jnp.round(numer / safe_dg / delta), lo, hi)
        q_rtn = jnp.clip(jnp.round(w_row / delta), lo, hi)
        q_new = jnp.where(dg <= EPS_DIAG, q_rtn, q_cd)

        r_new = w_row - delta * q_new
        p = p + g_col[:, None] * (r_new - r_old)[None, :]
        q = jax.lax.dynamic_update_slice_in_dim(q, q_new[None, :], i, 0)
        return p, q

    _, q = jax.lax.fori_loop(0, m, body, (p, q))
    qout_ref[...] = q


def comq_sweep(
    g: jnp.ndarray,
    w: jnp.ndarray,
    q: jnp.ndarray,
    delta: jnp.ndarray,
    lo: jnp.ndarray,
    hi: jnp.ndarray,
    tile: int = DEFAULT_TILE,
) -> jnp.ndarray:
    """One cyclic COMQ sweep; returns the updated bit-code matrix Q.

    g [m, m], w/q [m, n], delta/lo/hi [n]. n must divide into tiles of
    `tile` (otherwise one tile covers all columns; aot.py lowers per exact
    layer shape so no padding is needed there).
    """
    m, n = w.shape
    tn = min(tile, n)
    if n % tn != 0:
        # fall back to a single tile covering all columns
        tn = n
    grid = (n // tn,)
    kernel = functools.partial(_sweep_kernel, m=m)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, m), lambda j: (0, 0)),  # G: shared panel
            pl.BlockSpec((m, tn), lambda j: (0, j)),  # W tile
            pl.BlockSpec((m, tn), lambda j: (0, j)),  # Q tile
            pl.BlockSpec((tn,), lambda j: (j,)),  # delta tile
            pl.BlockSpec((tn,), lambda j: (j,)),  # lo tile
            pl.BlockSpec((tn,), lambda j: (j,)),  # hi tile
        ],
        out_specs=pl.BlockSpec((m, tn), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(g, w, q, delta, lo, hi)


def delta_update_per_channel(g, w, q, delta):
    """Eq. 10: delta_j = <X q_j, X w_j> / ||X q_j||^2 via the Gram matrix."""
    gq = jnp.dot(g, q, preferred_element_type=jnp.float32)
    num = jnp.sum(gq * w, axis=0)
    den = jnp.sum(gq * q, axis=0)
    return jnp.where(den > 0, num / den, delta)


def delta_update_per_layer(g, w, q, delta):
    """Eq. 7: scalar delta = <XQ, XW> / ||XQ||^2 via the Gram matrix."""
    gq = jnp.dot(g, q, preferred_element_type=jnp.float32)
    num = jnp.sum(gq * w)
    den = jnp.sum(gq * q)
    return jnp.where(den > 0, num / den, delta)


def comq_quantize(
    g: jnp.ndarray,
    w: jnp.ndarray,
    bits: int,
    iters: int = 3,
    lam: float = 1.0,
    per_channel: bool = True,
    tile: int = DEFAULT_TILE,
):
    """Full COMQ (init + K sweeps + delta updates), per-channel or
    per-layer, cyclic order. Greedy shared order is applied by permuting
    G/W before calling this and un-permuting Q after (see model.py).

    Returns (w_q, q, delta, z); delta/z are [n] vectors in both modes
    (per-layer broadcasts the shared scalar).
    """
    m, n = w.shape
    levels = jnp.float32(2.0**bits - 1.0)
    if per_channel:
        mx = jnp.max(w, axis=0)
        mn = jnp.min(w, axis=0)
        delta = lam * (mx - mn) / levels
        delta = jnp.where(delta <= 0, 1e-8, delta)
        z = jnp.round(mn / delta)
    else:
        d0 = jnp.mean(jnp.max(jnp.abs(w), axis=0)) / 2.0 ** (bits - 1)
        d0 = jnp.where(d0 <= 0, 1e-8, d0)
        delta = jnp.full((n,), d0, jnp.float32)
        z = jnp.full((n,), jnp.round(jnp.min(w) / d0), jnp.float32)
    q = w / delta[None, :]

    levels = 2.0**bits - 1.0
    for _ in range(iters):
        q = comq_sweep(g, w, q, delta, z, z + levels, tile)
        if per_channel:
            delta = delta_update_per_channel(g, w, q, delta)
        else:
            d = delta_update_per_layer(g, w, q, delta[0])
            delta = jnp.full((n,), d, jnp.float32)
    return q * delta[None, :], q, delta, z
