"""Pure-numpy oracle for COMQ — the correctness ground truth.

Everything here is deliberately written in the most literal possible
transcription of the paper's equations (Alg. 1 / Alg. 2, Eq. 6/7/9/10),
with no performance tricks. Both the Pallas kernel (comq_pallas.py) and
the Rust engines (rust/src/quant/comq.rs) are tested against these
functions.

Two mathematically equivalent formulations are provided:

  * residual domain — carries U = X (W - W_q)  in R^{b x n}  (Eq. 6/9
    verbatim);
  * Gram domain     — carries P = G (W - W_q)  in R^{m x n}  with
    G = X^T X precomputed. The layer-wise objective depends on X only
    through G, so the two are identical up to float reassociation.

Rounding is ties-to-even everywhere (numpy/jnp semantics; the Rust side
uses f32::round_ties_even to match).
"""

from __future__ import annotations

import numpy as np

EPS_DIAG = 1e-12  # guard for dead features (||x_i|| == 0)


# ---------------------------------------------------------------------------
# quantization grid helpers
# ---------------------------------------------------------------------------


def init_per_channel(w: np.ndarray, bits: int, lam: float = 1.0):
    """Per-channel asymmetric init (Sec. 3.2): delta_j, z_j for each column.

    delta_j = lam * (max(w_j) - min(w_j)) / (2^b - 1);  z_j = round(min/delta).
    """
    levels = 2.0**bits - 1.0
    mx = w.max(axis=0)
    mn = w.min(axis=0)
    delta = lam * (mx - mn) / levels
    delta = np.where(delta <= 0, 1e-8, delta).astype(np.float32)
    z = np.round(mn / delta).astype(np.float32)
    return delta, z


def init_per_layer(w: np.ndarray, bits: int):
    """Per-layer init (Sec. 3.1): shared scalar delta from the average
    column-wise infinity norm; shared zero point from min(W)."""
    delta = float(np.abs(w).max(axis=0).mean() / 2.0 ** (bits - 1))
    if delta <= 0:
        delta = 1e-8
    z = float(np.round(w.min() / delta))
    return np.float32(delta), np.float32(z)


def rtn(w: np.ndarray, bits: int, per_channel: bool = True, lam: float = 1.0):
    """Round-to-nearest baseline: W_q = delta * clip(round(W/delta))."""
    if per_channel:
        delta, z = init_per_channel(w, bits, lam)
    else:
        d, zz = init_per_layer(w, bits)
        delta = np.full(w.shape[1], d, np.float32)
        z = np.full(w.shape[1], zz, np.float32)
    q = np.clip(np.round(w / delta), z, z + 2.0**bits - 1.0)
    return (q * delta).astype(np.float32), q.astype(np.float32), delta, z


# ---------------------------------------------------------------------------
# greedy order (Sec. 3.3)
# ---------------------------------------------------------------------------


def greedy_order_per_column(diag_g: np.ndarray, w: np.ndarray) -> np.ndarray:
    """[m, n] int32: column j's row-update order, sorted by ||x_i|| * |w_ij|
    descending. 'cyclic' corresponds to arange(m) for every column."""
    score = np.sqrt(np.maximum(diag_g, 0.0))[:, None] * np.abs(w)
    return np.argsort(-score, axis=0, kind="stable").astype(np.int32)


def greedy_order_shared(diag_g: np.ndarray, w: np.ndarray) -> np.ndarray:
    """[m] int32: one order shared by all columns (vectorised variant);
    score_i = ||x_i|| * mean_j |w_ij|."""
    score = np.sqrt(np.maximum(diag_g, 0.0)) * np.abs(w).mean(axis=1)
    return np.argsort(-score, kind="stable").astype(np.int32)


# ---------------------------------------------------------------------------
# COMQ — residual domain (Eq. 6/9 verbatim)
# ---------------------------------------------------------------------------


def comq_per_channel_residual(
    x: np.ndarray,
    w: np.ndarray,
    bits: int,
    iters: int = 3,
    lam: float = 1.0,
    order: np.ndarray | None = None,
):
    """Alg. 2 carried in the residual domain. x [b, m], w [m, n].

    order: [m, n] per-column row orders (greedy) or None (cyclic).
    Returns (w_q, q, delta, z).
    """
    b, m = x.shape
    n = w.shape[1]
    levels = 2.0**bits - 1.0
    delta, z = init_per_channel(w, bits, lam)
    q = (w / delta).astype(np.float32)  # infeasible start, per the paper
    norms = (x * x).sum(axis=0)  # ||x_i||^2
    if order is None:
        order = np.tile(np.arange(m, dtype=np.int32)[:, None], (1, n))
    for _ in range(iters):
        u = x @ (w - q * delta)  # [b, n]
        for step in range(m):
            idx = order[step]  # [n] row index per column
            xg = x[:, idx]  # [b, n] gathered columns
            w_row = np.take_along_axis(w, idx[None, :], axis=0)[0]
            q_row = np.take_along_axis(q, idx[None, :], axis=0)[0]
            r_old = w_row - delta * q_row
            u1 = u - xg * r_old[None, :]
            numer = ((u1 + xg * w_row[None, :]) * xg).sum(axis=0)
            nrm = norms[idx]
            q_new = np.clip(
                np.round(numer / np.maximum(nrm, EPS_DIAG) / delta), z, z + levels
            ).astype(np.float32)
            q_new = np.where(nrm <= EPS_DIAG, np.clip(np.round(w_row / delta), z, z + levels), q_new)
            np.put_along_axis(q, idx[None, :], q_new[None, :], axis=0)
            u = u1 + xg * (w_row - delta * q_new)[None, :]
        # delta update (Eq. 10)
        xq = x @ q
        num = (xq * (x @ w)).sum(axis=0)
        den = (xq * xq).sum(axis=0)
        delta = np.where(den > 0, num / den, delta).astype(np.float32)
    return (q * delta).astype(np.float32), q, delta, z


def comq_per_layer_residual(
    x: np.ndarray,
    w: np.ndarray,
    bits: int,
    iters: int = 3,
    order: np.ndarray | None = None,
):
    """Alg. 1 carried in the residual domain (shared scalar delta/z)."""
    b, m = x.shape
    n = w.shape[1]
    levels = 2.0**bits - 1.0
    delta, z = init_per_layer(w, bits)
    q = (w / delta).astype(np.float32)
    norms = (x * x).sum(axis=0)
    if order is None:
        order = np.tile(np.arange(m, dtype=np.int32)[:, None], (1, n))
    for _ in range(iters):
        u = x @ (w - q * delta)
        for step in range(m):
            idx = order[step]
            xg = x[:, idx]
            w_row = np.take_along_axis(w, idx[None, :], axis=0)[0]
            q_row = np.take_along_axis(q, idx[None, :], axis=0)[0]
            r_old = w_row - delta * q_row
            u1 = u - xg * r_old[None, :]
            numer = ((u1 + xg * w_row[None, :]) * xg).sum(axis=0)
            nrm = norms[idx]
            q_new = np.clip(
                np.round(numer / np.maximum(nrm, EPS_DIAG) / delta), z, z + levels
            ).astype(np.float32)
            q_new = np.where(nrm <= EPS_DIAG, np.clip(np.round(w_row / delta), z, z + levels), q_new)
            np.put_along_axis(q, idx[None, :], q_new[None, :], axis=0)
            u = u1 + xg * (w_row - delta * q_new)[None, :]
        xq = x @ q
        num = float((xq * (x @ w)).sum())
        den = float((xq * xq).sum())
        if den > 0:
            delta = np.float32(num / den)
    return (q * delta).astype(np.float32), q, delta, z


# ---------------------------------------------------------------------------
# COMQ — Gram domain (the optimized formulation; X enters only via G)
# ---------------------------------------------------------------------------


def comq_per_channel_gram(
    g: np.ndarray,
    w: np.ndarray,
    bits: int,
    iters: int = 3,
    lam: float = 1.0,
    order: np.ndarray | None = None,
):
    """Alg. 2 carried in the Gram domain. g = X^T X [m, m], w [m, n]."""
    m, n = w.shape
    levels = 2.0**bits - 1.0
    delta, z = init_per_channel(w, bits, lam)
    q = (w / delta).astype(np.float32)
    diag = np.diag(g).copy()
    if order is None:
        order = np.tile(np.arange(m, dtype=np.int32)[:, None], (1, n))
    for _ in range(iters):
        p = g @ (w - q * delta)  # [m, n]
        for step in range(m):
            idx = order[step]  # [n]
            w_row = np.take_along_axis(w, idx[None, :], axis=0)[0]
            q_row = np.take_along_axis(q, idx[None, :], axis=0)[0]
            r_old = w_row - delta * q_row
            p_row = np.take_along_axis(p, idx[None, :], axis=0)[0]  # P[idx_j, j]
            dg = diag[idx]
            numer = p_row - dg * r_old + dg * w_row
            q_new = np.clip(
                np.round(numer / np.maximum(dg, EPS_DIAG) / delta), z, z + levels
            ).astype(np.float32)
            q_new = np.where(dg <= EPS_DIAG, np.clip(np.round(w_row / delta), z, z + levels), q_new)
            np.put_along_axis(q, idx[None, :], q_new[None, :], axis=0)
            r_new = w_row - delta * q_new
            p += g[:, idx] * (r_new - r_old)[None, :]
        num = ((g @ q) * w).sum(axis=0)
        den = ((g @ q) * q).sum(axis=0)
        delta = np.where(den > 0, num / den, delta).astype(np.float32)
    return (q * delta).astype(np.float32), q, delta, z


def comq_per_layer_gram(
    g: np.ndarray,
    w: np.ndarray,
    bits: int,
    iters: int = 3,
    order: np.ndarray | None = None,
):
    """Alg. 1 carried in the Gram domain (shared scalar delta/z)."""
    m, n = w.shape
    levels = 2.0**bits - 1.0
    delta, z = init_per_layer(w, bits)
    q = (w / delta).astype(np.float32)
    diag = np.diag(g).copy()
    if order is None:
        order = np.tile(np.arange(m, dtype=np.int32)[:, None], (1, n))
    for _ in range(iters):
        p = g @ (w - q * delta)
        for step in range(m):
            idx = order[step]
            w_row = np.take_along_axis(w, idx[None, :], axis=0)[0]
            q_row = np.take_along_axis(q, idx[None, :], axis=0)[0]
            r_old = w_row - delta * q_row
            p_row = np.take_along_axis(p, idx[None, :], axis=0)[0]
            dg = diag[idx]
            numer = p_row - dg * r_old + dg * w_row
            q_new = np.clip(
                np.round(numer / np.maximum(dg, EPS_DIAG) / delta), z, z + levels
            ).astype(np.float32)
            q_new = np.where(dg <= EPS_DIAG, np.clip(np.round(w_row / delta), z, z + levels), q_new)
            np.put_along_axis(q, idx[None, :], q_new[None, :], axis=0)
            r_new = w_row - delta * q_new
            p += g[:, idx] * (r_new - r_old)[None, :]
        gq = g @ q
        num = float((gq * w).sum())
        den = float((gq * q).sum())
        if den > 0:
            delta = np.float32(num / den)
    return (q * delta).astype(np.float32), q, delta, z


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------


def recon_error(g: np.ndarray, w: np.ndarray, w_q: np.ndarray) -> float:
    """||X W_q - X W||^2 computed from the Gram matrix: tr(D^T G D)."""
    d = (w_q - w).astype(np.float64)
    return float((d * (g.astype(np.float64) @ d)).sum())
