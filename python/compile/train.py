"""Build-time training of the model zoo on SynthImageNet.

The COMQ paper starts from pretrained ImageNet checkpoints (PyTorch /
timm). We substitute build-time training of the tiny zoo on the seeded
synthetic dataset: the point is that calibration features X come from a
*really trained* model, so the per-channel weight statistics and outlier
structure that PTQ sensitivity depends on are genuine.

Hand-rolled Adam (no optax in this environment); jax.grad is used *only*
here, at build time — the quantizers themselves are backprop-free, which
is the paper's claim.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data as synth
from .nets import build_model
from .nets.common import Tap


def cross_entropy(logits, labels, smooth: float = 0.0):
    n_cls = logits.shape[-1]
    logp = jax.nn.log_softmax(logits)
    onehot = jax.nn.one_hot(labels, n_cls)
    if smooth > 0:
        onehot = onehot * (1.0 - smooth) + smooth / n_cls
    return -jnp.mean(jnp.sum(onehot * logp, axis=-1))


def adam_init(params):
    return (
        {k: jnp.zeros_like(v) for k, v in params.items()},
        {k: jnp.zeros_like(v) for k, v in params.items()},
    )


def adam_step(params, grads, m, v, step, lr, b1=0.9, b2=0.999, eps=1e-8):
    out_p, out_m, out_v = {}, {}, {}
    for k in params:
        m2 = b1 * m[k] + (1 - b1) * grads[k]
        v2 = b2 * v[k] + (1 - b2) * grads[k] ** 2
        mh = m2 / (1 - b1**step)
        vh = v2 / (1 - b2**step)
        out_p[k] = params[k] - lr * mh / (jnp.sqrt(vh) + eps)
        out_m[k] = m2
        out_v[k] = v2
    return out_p, out_m, out_v


# per-model training recipes; DeiT-style entries use label smoothing
RECIPES = {
    "vit_s": dict(steps=500, lr=1e-3, smooth=0.0),
    "vit_b": dict(steps=500, lr=8e-4, smooth=0.0),
    "deit_s": dict(steps=600, lr=1e-3, smooth=0.1),
    "swin_t": dict(steps=500, lr=1e-3, smooth=0.0),
    "swin_s": dict(steps=500, lr=8e-4, smooth=0.1),
    "resnet_lite": dict(steps=600, lr=2e-3, smooth=0.0),
    "cnn_s": dict(steps=600, lr=2e-3, smooth=0.0),
    "mobilenet_lite": dict(steps=700, lr=2e-3, smooth=0.0),
}


def accuracy(forward, params, images, labels, batch: int = 256) -> float:
    hits = 0
    for i in range(0, len(images), batch):
        logits = forward(params, jnp.asarray(images[i : i + batch]), Tap())
        hits += int((np.argmax(np.asarray(logits), -1) == labels[i : i + batch]).sum())
    return hits / len(images)


def train_model(
    name: str,
    train_split,
    val_split,
    seed: int = 0,
    batch: int = 64,
    verbose: bool = True,
) -> tuple[dict, float]:
    """Train one zoo model; returns (numpy params, val top-1)."""
    init, forward, cfg = build_model(name)
    recipe = RECIPES[name]
    params = {k: jnp.asarray(v) for k, v in init(seed).items()}
    m, v = adam_init(params)
    imgs, labels = train_split

    def loss_fn(p, x, y):
        return cross_entropy(forward(p, x, Tap()), y, recipe["smooth"])

    @jax.jit
    def step_fn(p, m, v, x, y, step, lr):
        loss, grads = jax.value_and_grad(loss_fn)(p, x, y)
        p2, m2, v2 = adam_step(p, grads, m, v, step, lr)
        return p2, m2, v2, loss

    rng = np.random.default_rng(seed + 99)
    steps = recipe["steps"]
    t0 = time.time()
    loss = float("nan")
    for s in range(1, steps + 1):
        idx = rng.integers(0, len(imgs), batch)
        lr = recipe["lr"] * 0.5 * (1 + np.cos(np.pi * s / steps))  # cosine decay
        params, m, v, loss = step_fn(
            params, m, v, jnp.asarray(imgs[idx]), jnp.asarray(labels[idx]), s, lr
        )
        if verbose and s % 200 == 0:
            print(f"    [{name}] step {s}/{steps} loss={float(loss):.3f}")
    acc = accuracy(forward, params, *val_split)
    if verbose:
        print(f"    [{name}] done in {time.time() - t0:.1f}s val_top1={acc:.4f}")
    return {k: np.asarray(p) for k, p in params.items()}, acc
