"""AOT build driver: python runs ONCE here, never on the request path.

    python -m compile.aot --out-dir ../artifacts

Produces, under artifacts/:

    data/synth.cts            calibration + validation images/labels
    data/<model>.cts          trained checkpoint (flat name -> tensor)
    data/<model>.meta.json    training metadata (fp val top-1)
    hlo/<model>.forward.hlo.txt        (params..., x[B]) -> logits
    hlo/<model>.calib.hlo.txt          (params..., x[B]) -> per-layer (G,mn,mx)
    hlo/<model>.actq4.hlo.txt          fake-quantized-activation forward (A4)
    hlo/<model>.actq8.hlo.txt          fake-quantized-activation forward (A8)
    hlo/sweep_m<m>_n<n>_<pc|pl>.hlo.txt   COMQ sweep (L1 Pallas kernel)
    manifest.json             everything the Rust coordinator needs

Checkpoints are cached: a model is retrained only if its checkpoint file
is missing (delete artifacts/data/<model>.cts to force).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data as synth
from . import model as graphs
from . import train
from .export import read_cts, write_cts
from .nets import build_model
from .nets.cnn import CNN_CONFIGS
from .nets.cnn import quant_layers as cnn_layers
from .nets.vit import VIT_CONFIGS
from .nets.vit import quant_layers as vit_layers

AOT_BATCH = 64
N_TRAIN, N_CALIB, N_VAL = 8192, 2048, 2048
SWEEP_MODELS = ("vit_s", "resnet_lite", "cnn_s")  # PJRT-kernel engine targets

ALL_MODELS = list(VIT_CONFIGS) + list(CNN_CONFIGS)


def model_meta(name: str):
    """(family, cfg-dict, quant layer list)"""
    if name in VIT_CONFIGS:
        cfg = VIT_CONFIGS[name]
        layers = vit_layers(cfg)
        cd = dict(
            dim=cfg.dim, depth=cfg.depth, heads=cfg.heads, mlp=cfg.mlp,
            patch=cfg.patch, window=cfg.window, img=cfg.img, classes=cfg.classes,
        )
        return "vit", cd, layers
    cfg = CNN_CONFIGS[name]
    layers = cnn_layers(cfg)
    cd = dict(kind=cfg.kind, width=cfg.width, blocks=cfg.blocks, img=cfg.img, classes=cfg.classes)
    return "cnn", cd, layers


def layer_shapes(params: dict, layers: list[str]) -> list[dict]:
    out = []
    for nm in layers:
        w = params[f"{nm}/W"]
        grouped = nm.endswith("/dw")
        out.append(
            dict(name=nm, m=int(w.shape[0]), n=int(w.shape[1]), grouped=grouped)
        )
    return out


def ensure_checkpoint(name: str, splits, out_data: str, force: bool = False):
    ckpt = os.path.join(out_data, f"{name}.cts")
    meta = os.path.join(out_data, f"{name}.meta.json")
    if not force and os.path.exists(ckpt) and os.path.exists(meta):
        params = read_cts(ckpt)
        acc = json.load(open(meta))["fp_top1"]
        print(f"  [{name}] cached checkpoint (fp_top1={acc:.4f})")
        return params, acc
    print(f"  [{name}] training...")
    params, acc = train.train_model(name, splits["train"], splits["val"])
    write_cts(ckpt, params)
    json.dump({"fp_top1": acc, "trained_at": time.time()}, open(meta, "w"))
    return params, acc


def lower_model_graphs(name: str, params: dict, layers: list[str], out_hlo: str) -> dict:
    names = graphs.param_order(params)
    specs = [jax.ShapeDtypeStruct(params[k].shape, jnp.float32) for k in names]
    xspec = jax.ShapeDtypeStruct((AOT_BATCH, *params_img_shape(name)), jnp.float32)
    arts = {}

    fwd = graphs.make_forward(name, names)
    path = f"{name}.forward.hlo.txt"
    _write(out_hlo, path, graphs.lower_to_text(fwd, (*specs, xspec)))
    arts["forward"] = f"hlo/{path}"

    stats = graphs.make_calib_stats(name, names, layers)
    path = f"{name}.calib.hlo.txt"
    _write(out_hlo, path, graphs.lower_to_text(stats, (*specs, xspec)))
    arts["calib_stats"] = f"hlo/{path}"

    aspec = jax.ShapeDtypeStruct((len(layers), 2), jnp.float32)
    for bits in (4, 8):
        fq = graphs.make_forward_actq(name, names, layers, bits)
        path = f"{name}.actq{bits}.hlo.txt"
        _write(out_hlo, path, graphs.lower_to_text(fq, (*specs, aspec, xspec)))
        arts[f"forward_actq{bits}"] = f"hlo/{path}"
    return arts


def params_img_shape(name: str):
    _, cd, _ = model_meta(name)
    return (cd["img"], cd["img"], 3)


def _write(d: str, fname: str, text: str):
    p = os.path.join(d, fname)
    with open(p, "w") as f:
        f.write(text)
    print(f"    wrote {p} ({len(text) // 1024} KiB)")


def lower_sweeps(shape_set: set[tuple[int, int]], out_hlo: str) -> list[dict]:
    arts = []
    for m, n in sorted(shape_set):
        for pc in (True, False):
            fn = graphs.make_sweep(per_channel=pc)
            g = jax.ShapeDtypeStruct((m, m), jnp.float32)
            w = jax.ShapeDtypeStruct((m, n), jnp.float32)
            v = jax.ShapeDtypeStruct((n,), jnp.float32)
            tag = "pc" if pc else "pl"
            path = f"sweep_m{m}_n{n}_{tag}.hlo.txt"
            _write(out_hlo, path, graphs.lower_to_text(fn, (g, w, w, v, v, v)))
            arts.append(dict(m=m, n=n, per_channel=pc, path=f"hlo/{path}"))
    return arts


def export_fixtures(out_data: str) -> None:
    """Cross-language parity fixtures: reference COMQ outputs computed by
    the python oracle (kernels/ref.py) on seeded inputs. The Rust test
    rust/tests/cross_lang.rs replays the same configs and asserts code-
    level agreement — the strongest check that the two implementations
    are the same algorithm."""
    from .kernels import ref

    path = os.path.join(out_data, "fixtures.cts")
    if os.path.exists(path):
        print(f"  cached {path}")
        return
    rng = np.random.default_rng(12345)
    tensors: dict[str, np.ndarray] = {}
    cases = []
    for ci, (b, m, n, bits, per_channel, greedy, lam) in enumerate(
        [
            (64, 24, 12, 4, True, False, 1.0),
            (64, 24, 12, 3, True, True, 1.0),
            (48, 16, 8, 2, True, False, 0.71),
            (96, 32, 10, 4, False, False, 1.0),
            (96, 32, 10, 3, False, True, 1.0),
        ]
    ):
        x = rng.standard_normal((b, m)).astype(np.float32)
        w = (rng.standard_normal((m, n)) * 0.5).astype(np.float32)
        g = (x.T @ x).astype(np.float32)
        order = None
        if greedy:
            order = ref.greedy_order_per_column(np.diag(g), w)
        if per_channel:
            wq, q, delta, z = ref.comq_per_channel_gram(g, w, bits, iters=3, lam=lam, order=order)
            zv = z
        else:
            wq, q, delta, z = ref.comq_per_layer_gram(g, w, bits, iters=3, order=order)
            delta = np.full(n, delta, np.float32)
            zv = np.full(n, z, np.float32)
        pre = f"case{ci}"
        tensors[f"{pre}/x"] = x
        tensors[f"{pre}/w"] = w
        tensors[f"{pre}/q"] = q
        tensors[f"{pre}/delta"] = np.asarray(delta, np.float32)
        tensors[f"{pre}/zero"] = np.asarray(zv, np.float32)
        tensors[f"{pre}/meta"] = np.array(
            [bits, 1 if per_channel else 0, 1 if greedy else 0, lam], np.float32
        )
        cases.append(ci)
    tensors["num_cases"] = np.array(cases, np.int32)
    write_cts(path, tensors)
    print(f"  wrote {path}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", default="all", help="comma list or 'all'")
    ap.add_argument("--retrain", action="store_true")
    args = ap.parse_args()

    out = args.out_dir
    out_data = os.path.join(out, "data")
    out_hlo = os.path.join(out, "hlo")
    os.makedirs(out_data, exist_ok=True)
    os.makedirs(out_hlo, exist_ok=True)

    model_names = ALL_MODELS if args.models == "all" else args.models.split(",")

    print("== SynthImageNet ==")
    splits = synth.splits(n_train=N_TRAIN, n_calib=N_CALIB, n_val=N_VAL)
    data_path = os.path.join(out_data, "synth.cts")
    if not os.path.exists(data_path):
        write_cts(
            data_path,
            {
                "calib/images": splits["calib"][0],
                "calib/labels": splits["calib"][1],
                "val/images": splits["val"][0],
                "val/labels": splits["val"][1],
            },
        )
        print(f"  wrote {data_path}")
    else:
        print(f"  cached {data_path}")

    manifest: dict = {
        "batch": AOT_BATCH,
        "classes": synth.NUM_CLASSES,
        "img": synth.IMG,
        "data": "data/synth.cts",
        "models": {},
        "sweeps": [],
    }

    sweep_shapes: set[tuple[int, int]] = set()
    for name in model_names:
        print(f"== {name} ==")
        family, cfgd, layers = model_meta(name)
        params, acc = ensure_checkpoint(name, splits, out_data, args.retrain)
        arts = lower_model_graphs(name, params, layers, out_hlo)
        shapes = layer_shapes(params, layers)
        if name in SWEEP_MODELS:
            for s in shapes:
                if not s["grouped"]:
                    sweep_shapes.add((s["m"], s["n"]))
        manifest["models"][name] = {
            "family": family,
            "config": cfgd,
            "params": graphs.param_order(params),
            "quant_layers": shapes,
            "checkpoint": f"data/{name}.cts",
            "fp_top1": acc,
            "artifacts": arts,
        }

    print("== COMQ sweep kernels (L1) ==")
    manifest["sweeps"] = lower_sweeps(sweep_shapes, out_hlo)

    print("== cross-language fixtures ==")
    export_fixtures(out_data)

    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest: {os.path.join(out, 'manifest.json')}")


if __name__ == "__main__":
    main()
