"""L2 model zoo: shapes, tap coverage, and training-free sanity."""

import numpy as np
import pytest

import jax.numpy as jnp

from compile import data as synth
from compile.nets import MODEL_REGISTRY, build_model
from compile.nets.cnn import CNN_CONFIGS
from compile.nets.cnn import quant_layers as cnn_layers
from compile.nets.common import Tap
from compile.nets.vit import VIT_CONFIGS
from compile.nets.vit import quant_layers as vit_layers

ALL = list(VIT_CONFIGS) + list(CNN_CONFIGS)


@pytest.mark.parametrize("name", ALL)
def test_forward_shapes(name):
    init, fwd, cfg = build_model(name)
    params = {k: jnp.asarray(v) for k, v in init(0).items()}
    x = jnp.zeros((2, cfg.img, cfg.img, 3), jnp.float32)
    logits = fwd(params, x, Tap())
    assert logits.shape == (2, cfg.classes)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("name", ALL)
def test_stats_tap_visits_every_quant_layer(name):
    init, fwd, cfg = build_model(name)
    params = {k: jnp.asarray(v) for k, v in init(0).items()}
    x = jnp.asarray(np.random.default_rng(0).standard_normal((2, cfg.img, cfg.img, 3)), jnp.float32)
    tap = Tap(mode="stats")
    fwd(params, x, tap)
    expected = vit_layers(cfg) if name in VIT_CONFIGS else cnn_layers(cfg)
    assert set(tap.stats) == set(expected)
    # Gram dims match the weight rows
    for nm in expected:
        g = np.asarray(tap.stats[nm][0])
        w = np.asarray(params[f"{nm}/W"])
        if g.ndim == 3:  # grouped (depthwise)
            assert g.shape[1] == w.shape[0]
            assert g.shape[0] == w.shape[1]
        else:
            assert g.shape == (w.shape[0], w.shape[0])


@pytest.mark.parametrize("name", ["vit_s", "resnet_lite", "mobilenet_lite"])
def test_actq_tap_changes_output(name):
    init, fwd, cfg = build_model(name)
    params = {k: jnp.asarray(v) for k, v in init(0).items()}
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((2, cfg.img, cfg.img, 3)), jnp.float32)
    layers = vit_layers(cfg) if name in VIT_CONFIGS else cnn_layers(cfg)
    tap = Tap(mode="actq", bits=2)
    tap.act_params = {nm: (jnp.float32(0.5), jnp.float32(-2.0)) for nm in layers}
    out_q = fwd(params, x, tap)
    out_fp = fwd(params, x, Tap())
    assert not np.allclose(np.asarray(out_q), np.asarray(out_fp))
    assert np.isfinite(np.asarray(out_q)).all()


def test_registry_complete():
    for name in ALL:
        assert name in MODEL_REGISTRY


def test_dataset_determinism_and_balance():
    a = synth.make_split(256, seed=5)
    b = synth.make_split(256, seed=5)
    assert (a[0] == b[0]).all() and (a[1] == b[1]).all()
    c = synth.make_split(256, seed=6)
    assert not (a[0] == c[0]).all()
    # all classes appear in a reasonably sized split
    assert len(np.unique(a[1])) == synth.NUM_CLASSES


def test_swin_windowing_changes_attention():
    # same dims but window vs global must differ after random init
    from compile.nets.vit import ViTConfig, forward, init_params

    cfg_g = ViTConfig("g", dim=32, depth=2, heads=2, mlp=64, window=0)
    cfg_w = ViTConfig("w", dim=32, depth=2, heads=2, mlp=64, window=2)
    params = {k: jnp.asarray(v) for k, v in init_params(cfg_g, 0).items()}
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((2, 16, 16, 3)), jnp.float32)
    out_g = forward(cfg_g, params, x, Tap())
    out_w = forward(cfg_w, params, x, Tap())
    assert not np.allclose(np.asarray(out_g), np.asarray(out_w))
