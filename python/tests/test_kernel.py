"""L1 correctness: the Pallas COMQ sweep vs the pure-numpy oracle.

This is the CORE correctness signal for the kernel layer — hypothesis
sweeps shapes, bit-widths and schemes and asserts code-exact agreement
with ref.py (both use ties-to-even rounding, so on float32 inputs the
codes match exactly away from measure-zero ties).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import comq_pallas as cp
from compile.kernels import ref


def make_case(seed, b, m, n, scale=0.5):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((b, m)).astype(np.float32)
    w = (rng.standard_normal((m, n)) * scale).astype(np.float32)
    g = (x.T @ x).astype(np.float32)
    return x, w, g


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    m=st.integers(2, 40),
    n=st.integers(1, 24),
    bits=st.sampled_from([2, 3, 4, 8]),
    per_channel=st.booleans(),
)
def test_pallas_sweep_matches_oracle(seed, m, n, bits, per_channel):
    _, w, g = make_case(seed, 32, m, n)
    wq_p, q_p, d_p, z_p = cp.comq_quantize(
        jnp.array(g), jnp.array(w), bits, iters=2, per_channel=per_channel
    )
    if per_channel:
        _, q_r, d_r, z_r = ref.comq_per_channel_gram(g, w, bits, iters=2)
    else:
        _, q_r, d_r, z_r = ref.comq_per_layer_gram(g, w, bits, iters=2)
    agree = (np.asarray(q_p) == q_r).mean()
    assert agree > 0.995, f"only {agree:.3f} of codes agree"
    np.testing.assert_allclose(np.asarray(d_p).mean(), np.mean(d_r), rtol=2e-2)


@pytest.mark.parametrize("bits", [2, 3, 4])
@pytest.mark.parametrize("per_channel", [True, False])
def test_pallas_exact_small(bits, per_channel):
    _, w, g = make_case(7, 64, 48, 40)
    wq_p, q_p, *_ = cp.comq_quantize(
        jnp.array(g), jnp.array(w), bits, iters=3, per_channel=per_channel
    )
    fn = ref.comq_per_channel_gram if per_channel else ref.comq_per_layer_gram
    wq_r, q_r, *_ = fn(g, w, bits, iters=3)
    assert (np.asarray(q_p) == q_r).all()
    np.testing.assert_allclose(np.asarray(wq_p), wq_r, atol=1e-5)


def test_pallas_tiles_match_single_tile():
    # n = 256 tiles at 128; result must equal the single-tile run
    _, w, g = make_case(11, 48, 24, 256)
    a = cp.comq_quantize(jnp.array(g), jnp.array(w), 4, iters=2, tile=128)[1]
    b = cp.comq_quantize(jnp.array(g), jnp.array(w), 4, iters=2, tile=256)[1]
    assert (np.asarray(a) == np.asarray(b)).all()


def test_sweep_reduces_error_vs_rtn():
    x, w, g = make_case(13, 96, 32, 16)
    for bits in (2, 3, 4):
        wq, *_ = cp.comq_quantize(jnp.array(g), jnp.array(w), bits, iters=3)
        err_comq = ref.recon_error(g, w, np.asarray(wq))
        err_rtn = ref.recon_error(g, w, ref.rtn(w, bits)[0])
        assert err_comq < err_rtn


def test_residual_equals_gram_oracle():
    x, w, g = make_case(17, 64, 20, 10)
    for bits in (2, 4):
        wq_r, q_r, *_ = ref.comq_per_channel_residual(x, w, bits, iters=3)
        wq_g, q_g, *_ = ref.comq_per_channel_gram(g, w, bits, iters=3)
        assert (q_r == q_g).all()


def test_greedy_order_is_permutation():
    _, w, g = make_case(19, 32, 30, 8)
    order = ref.greedy_order_per_column(np.diag(g), w)
    for j in range(w.shape[1]):
        assert sorted(order[:, j]) == list(range(w.shape[0]))


def test_dead_feature_guard():
    x, w, g = make_case(23, 32, 10, 4)
    x[:, 3] = 0.0
    g = (x.T @ x).astype(np.float32)
    wq, q, d, z = cp.comq_quantize(jnp.array(g), jnp.array(w), 4, iters=2)
    assert np.isfinite(np.asarray(q)).all()
    levels = 15.0
    qn = np.asarray(q)
    assert (qn >= np.asarray(z)[None, :]).all()
    assert (qn <= np.asarray(z)[None, :] + levels).all()
