"""AOT lowering: HLO text emission, parameter-order stability, and the
anchor that keeps tail parameters in the calib graph signature."""

import numpy as np

import jax
import jax.numpy as jnp

from compile import model as graphs
from compile.nets import build_model
from compile.nets.vit import VIT_CONFIGS, quant_layers


def entry_input_arity(hlo_text: str) -> int:
    """Number of entry-computation inputs in HLO text."""
    layout = hlo_text.split("entry_computation_layout={(", 1)[1]
    inputs = layout.split(")->", 1)[0]
    return inputs.count("f32[")


def small_model():
    name = "vit_s"
    init, fwd, cfg = build_model(name)
    params = init(0)
    return name, params, quant_layers(cfg), cfg


def test_param_order_is_sorted():
    _, params, _, _ = small_model()
    order = graphs.param_order(params)
    assert order == sorted(params)
    flat = graphs.pack_params(params)
    back = graphs.unpack_params(order, flat)
    assert set(back) == set(params)


def test_forward_graph_lowers_to_hlo_text():
    name, params, layers, cfg = small_model()
    names = graphs.param_order(params)
    specs = [jax.ShapeDtypeStruct(params[k].shape, jnp.float32) for k in names]
    xspec = jax.ShapeDtypeStruct((2, cfg.img, cfg.img, 3), jnp.float32)
    fwd = graphs.make_forward(name, names)
    text = graphs.lower_to_text(fwd, (*specs, xspec))
    assert "HloModule" in text
    assert entry_input_arity(text) == len(names) + 1


def test_calib_graph_keeps_all_params():
    # the anchor output must keep head/W+head/b in the signature (XLA
    # would otherwise DCE them and the positional feed would break)
    name, params, layers, cfg = small_model()
    names = graphs.param_order(params)
    specs = [jax.ShapeDtypeStruct(params[k].shape, jnp.float32) for k in names]
    xspec = jax.ShapeDtypeStruct((2, cfg.img, cfg.img, 3), jnp.float32)
    stats = graphs.make_calib_stats(name, names, layers)
    text = graphs.lower_to_text(stats, (*specs, xspec))
    assert entry_input_arity(text) == len(names) + 1


def test_sweep_graph_output_shapes():
    fn = graphs.make_sweep(per_channel=True)
    m, n = 8, 6
    g = jnp.eye(m, dtype=jnp.float32) * 2.0
    w = jnp.asarray(np.random.default_rng(0).standard_normal((m, n)), jnp.float32)
    delta = jnp.full((n,), 0.1, jnp.float32)
    lo = jnp.full((n,), -8.0, jnp.float32)
    hi = jnp.full((n,), 7.0, jnp.float32)
    q0 = w / delta
    q1, d1 = fn(g, w, q0, delta, lo, hi)
    assert q1.shape == (m, n)
    assert d1.shape == (n,)
    # with an identity-ish Gram the sweep equals plain rounding
    expected = np.clip(np.round(np.asarray(w) / 0.1), -8, 7)
    np.testing.assert_array_equal(np.asarray(q1), expected)


def test_actq_graph_distinct_from_fp():
    name, params, layers, cfg = small_model()
    names = graphs.param_order(params)
    fwd_fp = graphs.make_forward(name, names)
    fwd_q = graphs.make_forward_actq(name, names, layers, bits=2)
    flat = [jnp.asarray(v) for v in graphs.pack_params(params)]
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((2, cfg.img, cfg.img, 3)), jnp.float32)
    actq = jnp.tile(jnp.asarray([[0.25, -2.0]], jnp.float32), (len(layers), 1))
    out_fp = fwd_fp(*flat, x)[0]
    out_q = fwd_q(*flat, actq, x)[0]
    assert not np.allclose(np.asarray(out_fp), np.asarray(out_q))
