"""CTS interchange format: python-side round-trip + hypothesis fuzzing.

The Rust reader (rust/src/tensorstore) parses the same bytes; its tests
include a hand-written fixture matching this writer.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.export import read_cts, write_cts


def test_roundtrip_basic(tmp_path):
    p = str(tmp_path / "t.cts")
    tensors = {
        "a/W": np.arange(6, dtype=np.float32).reshape(2, 3),
        "labels": np.array([1, -2, 3], np.int32),
        "scalarish": np.array([3.5], np.float32),
    }
    write_cts(p, tensors)
    back = read_cts(p)
    assert set(back) == set(tensors)
    for k in tensors:
        np.testing.assert_array_equal(back[k], tensors[k])
        assert back[k].dtype == tensors[k].dtype


@settings(max_examples=20, deadline=None)
@given(
    ndim=st.integers(1, 4),
    seed=st.integers(0, 1000),
    dtype=st.sampled_from(["f32", "i32"]),
)
def test_roundtrip_fuzz(ndim, seed, dtype):
    import tempfile

    rng = np.random.default_rng(seed)
    shape = tuple(rng.integers(1, 5, ndim))
    if dtype == "f32":
        arr = rng.standard_normal(shape).astype(np.float32)
    else:
        arr = rng.integers(-1000, 1000, shape).astype(np.int32)
    with tempfile.TemporaryDirectory() as d:
        p = f"{d}/fuzz{seed}.cts"
        write_cts(p, {"x": arr})
        back = read_cts(p)["x"]
    np.testing.assert_array_equal(back, arr)
    assert back.shape == arr.shape


def test_rejects_bad_magic(tmp_path):
    p = str(tmp_path / "bad.cts")
    with open(p, "wb") as f:
        f.write(b"NOPE\x00\x00\x00\x00")
    with pytest.raises(AssertionError):
        read_cts(p)


def test_float64_coerced(tmp_path):
    p = str(tmp_path / "f64.cts")
    write_cts(p, {"x": np.array([1.0, 2.0])})  # float64 input
    assert read_cts(p)["x"].dtype == np.float32
